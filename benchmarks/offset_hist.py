"""Paper Figs 5–7: accumulated memory-offset histograms h_O(x).

Fig 5/6: g=1 and g=3 at M=32 for row-major/Morton/Hilbert.
Fig 7: Morton block-size sweep (levels ⇒ block sizes 1, 4, 16).
Reports summary statistics of each histogram (full histograms go to CSV
if --csv is passed); the paper's qualitative claims are asserted by
tests/test_cache_model.py.
"""

from __future__ import annotations

import time

from repro.core import HILBERT, MORTON, ROW_MAJOR, OrderingSpec, offset_summary


def rows():
    out = []
    M = 32
    for g in (1, 3):  # Fig 5 and Fig 6
        for spec in (ROW_MAJOR, MORTON, HILBERT):
            t0 = time.perf_counter()
            s = offset_summary(spec, M, g)
            dt = (time.perf_counter() - t0) * 1e6
            out.append((f"fig5_6/offsets_g{g}_{spec.name}", dt,
                        f"n_distinct={s.n_distinct};mean_abs={s.mean_abs:.1f};"
                        f"p99_abs={s.p99_abs:.0f};"
                        f"frac_line64={s.frac_within_line:.3f}"))
    # Fig 7: Morton block sizes 1, 4, 16 <=> levels m, m-2, m-4 (M=32, m=5)
    for block, r in ((1, 5), (4, 3), (16, 1)):
        spec = OrderingSpec("morton", level=r)
        t0 = time.perf_counter()
        s = offset_summary(spec, M, 1)
        dt = (time.perf_counter() - t0) * 1e6
        out.append((f"fig7/offsets_morton_block{block}", dt,
                    f"n_distinct={s.n_distinct};mean_abs={s.mean_abs:.1f};"
                    f"frac_line64={s.frac_within_line:.3f}"))
    return out
