"""Paper Figs 8–10 / 12–14: time per grid-value update for 10 iterations.

gol3d with orderings ∈ {row-major, Morton, Hilbert}, stencil g ∈ {1, 2},
M ∈ {32, 64} (the paper's 64–256 scaled to this container's single CPU
core; the ordering *comparison* is the object, not absolute time).
Times the jit'd SFC-blocked update pipeline end-to-end.
"""

from __future__ import annotations

import time

import jax

from repro.core import HILBERT, MORTON, ROW_MAJOR
from repro.stencil import Gol3d, Gol3dConfig

N_ITERS = 10


def rows(sizes=(32, 64), stencils=(1, 2)):
    out = []
    for M in sizes:
        for g in stencils:
            for spec in (ROW_MAJOR, MORTON, HILBERT):
                app = Gol3d(Gol3dConfig(M=M, g=g, ordering=spec, block_T=8))
                step = app.step_fn()
                s = step(app.state_path)  # compile + warm
                s = jax.block_until_ready(s)
                t0 = time.perf_counter()
                for _ in range(N_ITERS):
                    s = step(s)
                jax.block_until_ready(s)
                dt = time.perf_counter() - t0
                per_item_ns = dt / N_ITERS / (M ** 3) * 1e9
                out.append((f"fig8_14/update_M{M}_g{g}_{spec.name}",
                            dt * 1e6 / N_ITERS,
                            f"ns_per_item={per_item_ns:.2f}"))
    return out
