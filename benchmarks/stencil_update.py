"""Paper Figs 8–10 / 12–14: time per grid-value update for 10 iterations.

gol3d with orderings ∈ {row-major, Morton, Hilbert}, stencil g ∈ {1, 2},
M ∈ {32, 64} (the paper's 64–256 scaled to this container's single CPU
core; the ordering *comparison* is the object, not absolute time).
Times the jit'd SFC-blocked update pipeline end-to-end.

The ``resident/`` rows compare the pipeline forms (DESIGN.md §3–§4) on
the same workload: per-step *repack* (blockize_with_halo every step) vs
the fused *resident* block store at temporal-blocking depths S ∈ {1, 4}
(stencil/pipeline.py). ``derived`` carries the modelled per-substep HBM
bytes of every form — all computed by the pipeline's shared accounting
helpers (one source of truth, asserted consistent in
tests/test_fused_stencil.py): the fused path at S=4 must model ≥ 2×
fewer bytes/substep than the PR-1 unfused resident path, which itself
beats repack for K ≥ 2.

The ``clamped/`` rows run the same fused pipeline under the neumann0
physical boundary (DESIGN.md §8): timing includes the per-substep ghost
refresh, and ``derived`` adds the clamped exchange surface of a 2×2×2
mesh shard (mean and corner) next to the periodic ICI model — the
perf-trajectory record that edge shards exchange strictly fewer bytes.

The ``multifield/`` rows run the C=2 ``wave`` workload through the same
fused pipeline (DESIGN.md §9): every derived model key carries the ×C
``fields`` factor (asserted against the shared helpers in
tests/test_multifield.py), recording that a multi-field timestep
streams exactly C× the single-field bytes — HBM and ICI alike.
Every row stamps its ``fields`` so the perf trajectory can pin the
channel dimension per row (benchmarks/run.py --json).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HILBERT, MORTON, NEUMANN0, ROW_MAJOR
from repro.stencil import (Gol3d, Gol3dConfig, ResidentPipeline,
                           distributed_bytes_per_step, exchange_bytes_per_step,
                           repack_bytes_per_step, resident_bytes_per_step,
                           resident_unfused_bytes_per_step)

N_ITERS = 10
CLAMPED_PROCS = (2, 2, 2)  # mesh shape of the modelled clamped shard rows
WAVE_FIELDS = 2            # C of the multifield/ wave rows


def rows(sizes=(32, 64), stencils=(1, 2)):
    out = []
    for M in sizes:
        for g in stencils:
            for spec in (ROW_MAJOR, MORTON, HILBERT):
                app = Gol3d(Gol3dConfig(M=M, g=g, ordering=spec, block_T=8))
                step = app.step_fn()
                s = step(app.state_path)  # compile + warm
                s = jax.block_until_ready(s)
                t0 = time.perf_counter()
                for _ in range(N_ITERS):
                    s = step(s)
                jax.block_until_ready(s)
                dt = time.perf_counter() - t0
                per_item_ns = dt / N_ITERS / (M ** 3) * 1e9
                out.append((f"fig8_14/update_M{M}_g{g}_{spec.name}",
                            dt * 1e6 / N_ITERS,
                            f"ns_per_item={per_item_ns:.2f}"))
    out += resident_rows(sizes=sizes, stencils=stencils)
    out += clamped_rows(sizes=sizes)
    out += multifield_rows(sizes=sizes)
    out += checkpoint_rows(M=min(sizes))
    return out


def resident_derived(M: int, T: int, g: int, S: int, n_steps: int) -> str:
    """Shared-accounting derived string for one resident row.

    Reports the fused model alongside the PR-1 unfused and repack
    models, plus the distributed totals (HBM + modelled ICI for a mesh
    shard of the same local M — DESIGN.md §7), so the perf trajectory
    shows every pipeline form on every row.
    """
    fus_b = resident_bytes_per_step(M, T, g, n_steps, S=S)
    unf_b = resident_unfused_bytes_per_step(M, T, g, n_steps)
    rep_b = repack_bytes_per_step(M, T, g)
    exc_b = exchange_bytes_per_step(M, g, S)
    dst_b = distributed_bytes_per_step(M, T, g, n_steps, S=S)
    return (f"S={S};fields=1"
            f";fused_bytes_per_substep={fus_b:.0f}"
            f";unfused_bytes_per_step={unf_b:.0f}"
            f";repack_bytes_per_step={rep_b:.0f}"
            f";fused_vs_unfused={unf_b / fus_b:.3f}"
            f";fused_vs_repack={rep_b / fus_b:.3f}"
            f";ici_bytes_per_step={exc_b:.0f}"
            f";distributed_bytes_per_step={dst_b:.0f}")


def clamped_derived(M: int, T: int, g: int, S: int, n_steps: int) -> str:
    """Shared-accounting derived string for one clamped row.

    The HBM term is boundary-independent (same fused model); the ICI
    columns report the clamped exchange surface of a CLAMPED_PROCS mesh
    — the mesh mean DistributedPipeline.plan(bc=...) minimises and the
    corner shard — alongside the periodic torus volume for the ratio.
    """
    fus_b = resident_bytes_per_step(M, T, g, n_steps, S=S)
    per_b = exchange_bytes_per_step(M, g, S)
    mean_b = exchange_bytes_per_step(M, g, S, bc=NEUMANN0,
                                     procs=CLAMPED_PROCS)
    corner_b = exchange_bytes_per_step(M, g, S, bc=NEUMANN0,
                                       procs=CLAMPED_PROCS,
                                       coords=(0, 0, 0))
    dst_b = distributed_bytes_per_step(M, T, g, n_steps, S=S, bc=NEUMANN0,
                                       procs=CLAMPED_PROCS)
    return (f"S={S};bc=neumann0;fields=1"
            f";fused_bytes_per_substep={fus_b:.0f}"
            f";ici_bytes_per_step_periodic={per_b:.0f}"
            f";ici_bytes_per_step_clamped={mean_b:.0f}"
            f";ici_bytes_per_step_edge_shard={corner_b:.0f}"
            f";ici_clamped_vs_periodic={mean_b / per_b:.3f}"
            f";distributed_bytes_per_step={dst_b:.0f}")


def clamped_rows(sizes=(32, 64), g=1, T=8, n_steps=N_ITERS):
    """Fused resident pipeline under neumann0 boundaries (DESIGN.md §8):
    steps/sec with the per-substep ghost refresh in the hot loop, plus
    the clamped exchange-surface model of a CLAMPED_PROCS mesh shard."""
    out = []
    for M in sizes:
        cube = Gol3d(Gol3dConfig(M=M, g=g, block_T=T)).cube
        for S in (1, 4):
            for kind in ("morton", "hilbert"):
                pipe = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=S,
                                        bc=NEUMANN0)
                run = pipe.run_fn(n_steps)
                jax.block_until_ready(run(pipe.to_blocks(cube)))  # warm
                store = pipe.to_blocks(cube)
                t0 = time.perf_counter()
                jax.block_until_ready(run(store))
                dt = time.perf_counter() - t0
                out.append((
                    f"clamped/update_M{M}_g{g}_T{T}_S{S}_{kind}",
                    dt * 1e6 / n_steps,
                    f"steps_per_s={n_steps / dt:.1f};"
                    + clamped_derived(M, T, g, S, n_steps),
                ))
    return out


def multifield_derived(M: int, T: int, g: int, S: int, n_steps: int,
                       C: int = WAVE_FIELDS) -> str:
    """Shared-accounting derived string for one multi-field (wave) row.

    Every model key carries the ×C ``fields`` factor (DESIGN.md §9):
    the fused HBM stream moves C windows + C tiles per block, the deep
    exchange packs C channels per face, and the distributed total is
    their sum. ``fused_bytes_per_field_substep`` divides back to the
    per-channel stream — equal to the C=1 fused model, the record that
    the multi-field store adds *no* overhead beyond the ×C payload.
    """
    fus_b = resident_bytes_per_step(M, T, g, n_steps, S=S, fields=C)
    one_b = resident_bytes_per_step(M, T, g, n_steps, S=S)
    exc_b = exchange_bytes_per_step(M, g, S, fields=C)
    dst_b = distributed_bytes_per_step(M, T, g, n_steps, S=S, fields=C)
    return (f"S={S};fields={C}"
            f";fused_bytes_per_substep={fus_b:.0f}"
            f";fused_bytes_per_field_substep={fus_b / C:.0f}"
            f";fused_vs_single_field={fus_b / one_b:.3f}"
            f";ici_bytes_per_step={exc_b:.0f}"
            f";distributed_bytes_per_step={dst_b:.0f}")


def multifield_rows(sizes=(32, 64), g=1, T=8, n_steps=N_ITERS):
    """C=2 wave workload through the fused resident pipeline
    (DESIGN.md §9): steps/sec on the stacked (2, nb, T³) store, plus the
    ×C bytes model the accounting tests pin."""
    out = []
    rng = np.random.default_rng(0)
    for M in sizes:
        fields = jnp.asarray(
            rng.normal(size=(WAVE_FIELDS, M, M, M)).astype(np.float32))
        for S in (1, 4):
            for kind in ("morton", "hilbert"):
                pipe = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=S,
                                        rule="wave")
                run = pipe.run_fn(n_steps)
                jax.block_until_ready(run(pipe.to_blocks(fields)))  # warm
                store = pipe.to_blocks(fields)
                t0 = time.perf_counter()
                jax.block_until_ready(run(store))
                dt = time.perf_counter() - t0
                out.append((
                    f"multifield/update_M{M}_g{g}_T{T}_S{S}"
                    f"_C{WAVE_FIELDS}_{kind}",
                    dt * 1e6 / n_steps,
                    f"steps_per_s={n_steps / dt:.1f};"
                    + multifield_derived(M, T, g, S, n_steps),
                ))
    return out


def checkpoint_rows(M=32, g=1, T=8, S=4, intervals=(16, 64), n_steps=64):
    """Checkpoint overhead of the fault-tolerant runner (DESIGN.md §10):
    a CheckpointedRun vs the plain fused run over the same n_steps, at
    interval ∈ {16, 64}.

    ``derived`` stamps both sides of the model/measure pair: the
    modelled snapshot bytes per interval (`ckpt_bytes_per_interval`,
    deterministic — CI pins it exactly) next to the bytes actually on
    disk for one checkpoint dir (`ckpt_bytes_measured`, npz + manifest
    container overhead included), and the modelled traffic fraction
    (`ckpt_model_fraction`, shared accounting) next to the measured
    wall-clock fraction spent checkpointing (`ckpt_wall_fraction`).
    """
    import os
    import shutil
    import tempfile

    from repro.stencil import (CheckpointedRun, checkpoint_bytes_per_interval,
                               checkpoint_traffic_fraction)

    out = []
    rng = np.random.default_rng(0)
    state0 = (rng.random((M, M, M)) < 0.35).astype(np.float32)
    pipe = ResidentPipeline(M=M, T=T, g=g, kind="hilbert", S=S)
    # plain fused run (no checkpointing), same chunk structure as the
    # runner would use so the comparison isolates snapshot+write cost
    run = pipe.run_fn(n_steps)
    jax.block_until_ready(run(pipe.to_blocks(jnp.asarray(state0))))  # warm
    store = pipe.to_blocks(jnp.asarray(state0))
    t0 = time.perf_counter()
    jax.block_until_ready(run(store))
    t_plain = time.perf_counter() - t0
    for interval in intervals:
        d = tempfile.mkdtemp(prefix="bench_ckpt_")
        try:
            cr = CheckpointedRun(pipe, d, interval=interval)
            cr.run(state0, n_steps)  # warm (compiles the chunk runners)
            shutil.rmtree(d)
            t0 = time.perf_counter()
            cr.run(state0, n_steps)
            t_ckpt = time.perf_counter() - t0
            step_dir = os.path.join(d, f"step_{interval:08d}")
            measured = sum(
                os.path.getsize(os.path.join(step_dir, f))
                for f in os.listdir(step_dir))
        finally:
            shutil.rmtree(d, ignore_errors=True)
        model_b = checkpoint_bytes_per_interval(M)
        model_f = checkpoint_traffic_fraction(M, T, g, interval, S=S)
        wall_f = max(0.0, t_ckpt - t_plain) / t_ckpt
        out.append((
            f"checkpoint/run_M{M}_g{g}_T{T}_S{S}_int{interval}",
            t_ckpt * 1e6 / n_steps,
            f"steps_per_s={n_steps / t_ckpt:.1f};fields=1"
            f";ckpt_interval={interval}"
            f";ckpt_bytes_per_interval={model_b}"
            f";ckpt_bytes_measured={measured}"
            f";ckpt_model_fraction={model_f:.4f}"
            f";ckpt_wall_fraction={wall_f:.4f}",
        ))
    return out


def resident_rows(sizes=(32, 64), stencils=(1, 2), T=8, n_steps=N_ITERS):
    """Fused resident pipeline at S ∈ {1, 4}: steps/sec (jnp path,
    end-to-end) + the modelled bytes of fused/unfused/repack forms."""
    out = []
    for M in sizes:
        for g in stencils:
            cube = Gol3d(Gol3dConfig(M=M, g=g, block_T=T)).cube
            for S in (1, 4):
                if S * g > T or T % (S * g):
                    continue
                for kind in ("morton", "hilbert"):
                    pipe = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=S)
                    run = pipe.run_fn(n_steps)
                    jax.block_until_ready(run(pipe.to_blocks(cube)))  # warm
                    store = pipe.to_blocks(cube)
                    t0 = time.perf_counter()
                    jax.block_until_ready(run(store))
                    dt = time.perf_counter() - t0
                    out.append((
                        f"resident/update_M{M}_g{g}_T{T}_S{S}_{kind}",
                        dt * 1e6 / n_steps,
                        f"steps_per_s={n_steps / dt:.1f};"
                        + resident_derived(M, T, g, S, n_steps),
                    ))
    return out
