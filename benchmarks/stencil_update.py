"""Paper Figs 8–10 / 12–14: time per grid-value update for 10 iterations.

gol3d with orderings ∈ {row-major, Morton, Hilbert}, stencil g ∈ {1, 2},
M ∈ {32, 64} (the paper's 64–256 scaled to this container's single CPU
core; the ordering *comparison* is the object, not absolute time).
Times the jit'd SFC-blocked update pipeline end-to-end.

The ``resident/`` rows compare the two pipeline forms (DESIGN.md §3) on
the same workload: per-step *repack* (blockize_with_halo every step)
vs the fused *resident* block store (stencil/pipeline.py). ``derived``
carries the modelled per-step HBM bytes of each form — the resident
path must move strictly fewer bytes for K ≥ 2 since it has no
((T+2g)/T)³ halo duplication and no per-step O(M³) repack.
"""

from __future__ import annotations

import time

import jax

from repro.core import HILBERT, MORTON, ROW_MAJOR
from repro.stencil import (Gol3d, Gol3dConfig, ResidentPipeline,
                           repack_bytes_per_step, resident_bytes_per_step)

N_ITERS = 10


def rows(sizes=(32, 64), stencils=(1, 2)):
    out = []
    for M in sizes:
        for g in stencils:
            for spec in (ROW_MAJOR, MORTON, HILBERT):
                app = Gol3d(Gol3dConfig(M=M, g=g, ordering=spec, block_T=8))
                step = app.step_fn()
                s = step(app.state_path)  # compile + warm
                s = jax.block_until_ready(s)
                t0 = time.perf_counter()
                for _ in range(N_ITERS):
                    s = step(s)
                jax.block_until_ready(s)
                dt = time.perf_counter() - t0
                per_item_ns = dt / N_ITERS / (M ** 3) * 1e9
                out.append((f"fig8_14/update_M{M}_g{g}_{spec.name}",
                            dt * 1e6 / N_ITERS,
                            f"ns_per_item={per_item_ns:.2f}"))
    out += resident_rows(sizes=sizes, stencils=stencils)
    return out


def resident_rows(sizes=(32, 64), stencils=(1, 2), T=8, n_steps=N_ITERS):
    """Repack vs resident: steps/sec (jnp path, end-to-end) + modelled bytes."""
    out = []
    for M in sizes:
        for g in stencils:
            rep_b = repack_bytes_per_step(M, T, g)
            res_b = resident_bytes_per_step(M, T, g, n_steps)
            for kind in ("morton", "hilbert"):
                pipe = ResidentPipeline(M=M, T=T, g=g, kind=kind)
                app = Gol3d(Gol3dConfig(M=M, g=g, block_T=T))
                cube = app.cube
                run = pipe.run_fn(n_steps)
                store = jax.block_until_ready(run(pipe.to_blocks(cube)))  # warm
                store = pipe.to_blocks(cube)
                t0 = time.perf_counter()
                store = jax.block_until_ready(run(store))
                dt = time.perf_counter() - t0
                out.append((
                    f"resident/update_M{M}_g{g}_T{T}_{kind}",
                    dt * 1e6 / n_steps,
                    f"steps_per_s={n_steps / dt:.1f}"
                    f";resident_bytes_per_step={res_b:.0f}"
                    f";repack_bytes_per_step={rep_b:.0f}"
                    f";bytes_ratio={res_b / rep_b:.3f}",
                ))
    return out
