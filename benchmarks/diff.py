"""Perf-trajectory differ: compare two rev-stamped ``BENCH_*.json`` files.

CI uploads a ``{"git_rev", "rows"}`` JSON per push (benchmarks/run.py
--json); this tool diffs two of them row by row and **exits nonzero** on
any regression beyond the threshold, so a PR that slows a benchmarked
path turns the pipeline red against the previous artifact.

    python -m benchmarks.diff OLD.json NEW.json [--threshold PCT]
                              [--min-us US] [--keys k1,k2,...]
                              [--keys-threshold PCT]

- timings: a row regresses when ``new.us_per_call`` exceeds
  ``max(old.us_per_call, MIN_US) * (1 + PCT/100)`` — the baseline is
  floored at ``--min-us`` (default 50 µs) so sub-noise-floor rows can't
  flag on jitter, yet a formerly-tiny row that turns slow still trips;
- ``--keys``: comma-separated *derived* numeric keys (e.g. the modelled
  ``fused_bytes_per_substep``) gated at ``--keys-threshold`` (default:
  0 — any increase fails). These are deterministic model outputs, not
  timings: noise is impossible, so CI pins them exactly while keeping a
  generous timing threshold for its noisy runners. An intentional model
  change shows up as a red diff to be acknowledged by rebaselining
  (decreases and renames only note);
- rows present on only one side are reported but never fail the diff
  (benchmarks come and go across PRs).
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> tuple[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    return payload.get("git_rev", "unknown"), {
        r["name"]: r for r in payload["rows"]}


def compare(old: dict, new: dict, threshold: float, min_us: float,
            keys: list[str], keys_threshold: float | None = None
            ) -> tuple[list[str], list[str]]:
    """(regressions, notes) — human-readable lines per affected row.

    ``keys_threshold`` gates the derived model keys independently of the
    (noise-tolerant) timing threshold; None falls back to ``threshold``
    (the pre-tightening behaviour).
    """
    regressions, notes = [], []
    factor = 1.0 + threshold / 100.0
    kfactor = factor if keys_threshold is None \
        else 1.0 + keys_threshold / 100.0
    for name in sorted(set(old) | set(new)):
        if name not in old:
            notes.append(f"+ {name} (new row)")
            continue
        if name not in new:
            notes.append(f"- {name} (row removed)")
            continue
        o, n = old[name], new[name]
        ou, nu = o["us_per_call"], n["us_per_call"]
        # baseline floored at min_us: sub-noise-floor rows can't trip the
        # gate by jitter, but a formerly-fast row blowing past the floor
        # by more than the threshold still registers
        if nu >= min_us and nu > max(ou, min_us) * factor:
            regressions.append(
                f"{name}: us_per_call {ou:.1f} -> {nu:.1f} "
                f"(+{(nu / ou - 1) * 100:.0f}% > {threshold:.0f}%)")
        for k in keys:
            ov, nv = o["derived"].get(k), n["derived"].get(k)
            if isinstance(ov, (int, float)) and nv is None:
                # a still-present row stopped emitting a pinned key: the
                # model output went dark, which must at least be visible
                # (never fatal — key schemas evolve like rows do)
                notes.append(f"~ {name}: {k} disappeared (was {ov:.0f})")
                continue
            if not isinstance(ov, (int, float)) or \
                    not isinstance(nv, (int, float)) or ov <= 0:
                continue
            if nv > ov * kfactor:
                regressions.append(
                    f"{name}: {k} {ov:.0f} -> {nv:.0f} "
                    f"(+{(nv / ov - 1) * 100:.0f}% > "
                    f"{(kfactor - 1) * 100:.0f}%)")
            elif nv != ov:
                notes.append(f"~ {name}: {k} {ov:.0f} -> {nv:.0f}")
    return regressions, notes


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.diff",
        description="flag >X%% per-row regressions between two bench JSONs")
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--threshold", type=float, default=25.0,
                    help="regression threshold in percent (default 25)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="ignore timing rows faster than this (noise floor)")
    ap.add_argument("--keys", default="",
                    help="comma-separated derived numeric keys to also diff")
    ap.add_argument("--keys-threshold", type=float, default=0.0,
                    help="threshold for --keys (deterministic model "
                         "outputs; default 0 — any increase fails)")
    args = ap.parse_args(argv)

    old_rev, old = load_rows(args.old)
    new_rev, new = load_rows(args.new)
    keys = [k for k in args.keys.split(",") if k]
    regressions, notes = compare(old, new, args.threshold, args.min_us, keys,
                                 keys_threshold=args.keys_threshold)

    print(f"# bench diff: {old_rev} -> {new_rev} "
          f"({len(old)} -> {len(new)} rows, threshold {args.threshold:.0f}%)")
    for line in notes:
        print(line)
    if regressions:
        print(f"\n{len(regressions)} regression(s):", file=sys.stderr)
        for line in regressions:
            print(f"  REGRESSION {line}", file=sys.stderr)
        return 1
    print("no regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
