"""Pallas kernel benchmarks: schedule-locality scoring + interpret timing.

The real object here is structural (this container has no TPU): the
paper's LRU cache model (core/cache_model.simulate_lru) re-parameterised
for VMEM scores the *block fetch stream* of each flash-attention
schedule — row-major vs Morton vs Hilbert traversal of the (q,kv) block
grid. A "line" is one block; capacity c is how many blocks fit VMEM.
Fewer misses = fewer HBM→VMEM DMAs = lower memory term on TPU.

Also times the interpret-mode kernels (CPU correctness path) so
regressions are visible.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_model import simulate_lru
from repro.core.layout import blockize, blockize_with_halo
from repro.core.neighbors import FACE_COLS, SELF_COL, neighbor_table, neighbor_table_device
from repro.kernels.flash_attn import build_schedule, flash_attention_fwd
from repro.kernels.ops import uniform_weights
from repro.kernels.stencil3d import (stencil_step_fused, stencil_sum_blocks,
                                     stencil_sum_resident)
from repro.stencil.pipeline import (fused_items_per_launch,
                                    repack_items_per_step,
                                    resident_unfused_items_per_step)


def _attention_block_stream(nq, nk, kind, causal=True):
    """Sequence of distinct (kind, block) VMEM fetches for a schedule."""
    iq, ik = build_schedule(nq, nk, causal=causal, block_q=1, block_k=1,
                            kind=kind)
    stream = []
    for a, b in zip(iq.tolist(), ik.tolist()):
        stream.append(("q", a))
        stream.append(("k", b))
        stream.append(("v", b))
    ids = {}
    return np.array([ids.setdefault(s, len(ids)) for s in stream])


def attention_schedule_rows(nq: int = 32, nk: int = 32, vmem_blocks: int = 24):
    out = []
    for kind in ("row_major", "morton", "hilbert"):
        t0 = time.perf_counter()
        stream = _attention_block_stream(nq, nk, kind)
        misses = simulate_lru(stream, vmem_blocks)
        dt = (time.perf_counter() - t0) * 1e6
        hbm_refetch = misses / (nq + 2 * nk)  # 1.0 = each block fetched once
        out.append((f"kernel/flash_sched_{kind}_nq{nq}", dt,
                    f"vmem_misses={misses};refetch_factor={hbm_refetch:.2f}"))
    return out


def stencil_block_rows(nt: int = 8, vmem_blocks: int = 8):
    """Stencil block walk: consecutive blocks share halos; the LRU model
    counts how often a neighbour block is still VMEM-resident. The fetch
    stream is exactly what the resident kernel's index maps emit: the
    block itself plus its -x/-y/-z face neighbours from the SFC
    neighbour table (core/neighbors.py)."""
    out = []
    lo_cols = FACE_COLS[0], FACE_COLS[2], FACE_COLS[4]  # k-, i-, j-
    for kind in ("row_major", "morton", "hilbert"):
        t0 = time.perf_counter()
        tab = neighbor_table(kind, nt)  # (nb, 27) path->path, periodic
        stream = []
        for t in range(nt ** 3):
            stream.append(int(tab[t, SELF_COL]))
            for col in lo_cols:
                stream.append(int(tab[t, col]))
        misses = simulate_lru(np.asarray(stream), vmem_blocks)
        dt = (time.perf_counter() - t0) * 1e6
        out.append((f"kernel/stencil_walk_{kind}_nt{nt}", dt,
                    f"vmem_misses={misses};min_possible={nt**3}"))
    return out


def interpret_timing_rows():
    rng = np.random.default_rng(0)
    out = []
    # stencil kernel
    blocks = jnp.asarray(rng.normal(size=(8, 10, 10, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3)).astype(np.float32))
    stencil_sum_blocks(blocks, w, g=1)  # compile
    t0 = time.perf_counter()
    for _ in range(5):
        r = stencil_sum_blocks(blocks, w, g=1)
    jax.block_until_ready(r)
    out.append(("kernel/stencil3d_interpret", (time.perf_counter() - t0) / 5 * 1e6,
                "T=8;g=1;nb=8"))
    # flash attention kernel
    q = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
    flash_attention_fwd(q, q, q, causal=True, block_q=32, block_k=32)
    t0 = time.perf_counter()
    for _ in range(5):
        r = flash_attention_fwd(q, q, q, causal=True, block_q=32, block_k=32)
    jax.block_until_ready(r)
    out.append(("kernel/flash_attn_interpret", (time.perf_counter() - t0) / 5 * 1e6,
                "S=128;D=32;morton"))
    return out


def resident_kernel_rows(M: int = 16, T: int = 8, g: int = 1,
                         kind: str = "hilbert", S: int = 4):
    """Repack vs resident vs fused-temporal kernel on the same cube
    (interpret mode, CPU): times all three forms. The modelled per-
    substep HBM stream comes from stencil/pipeline.py's shared
    accounting helpers — the same numbers benchmarks/stencil_update.py
    reports, asserted consistent in tests/test_fused_stencil.py."""
    rng = np.random.default_rng(0)
    cube = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(2 * g + 1,) * 3).astype(np.float32))
    nb = (M // T) ** 3
    out = []

    halo = blockize_with_halo(cube, T, g, kind=kind)
    stencil_sum_blocks(halo, w, g=g)  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        # the repack form rebuilds the halo store every step
        r = stencil_sum_blocks(blockize_with_halo(cube, T, g, kind=kind), w, g=g)
    jax.block_until_ready(r)
    out.append((f"kernel/stencil_repack_interpret_{kind}",
                (time.perf_counter() - t0) / 3 * 1e6,
                f"T={T};g={g};nb={nb}"
                f";hbm_items_per_substep={repack_items_per_step(M, T, g)}"))

    store = blockize(cube, T, kind=kind)
    nbr = neighbor_table_device(kind, M // T)
    stencil_sum_resident(store, w, nbr, g=g)  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        r = stencil_sum_resident(store, w, nbr, g=g)
    jax.block_until_ready(r)
    out.append((f"kernel/stencil_resident_interpret_{kind}",
                (time.perf_counter() - t0) / 3 * 1e6,
                f"T={T};g={g};nb={nb}"
                f";hbm_items_per_substep={resident_unfused_items_per_step(M, T, g)}"))

    # fused temporal blocking: S whole gol substeps per launch
    gw = uniform_weights(g)
    stencil_step_fused(store, gw, nbr, g=g, S=S, rule="gol")  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        r = stencil_step_fused(store, gw, nbr, g=g, S=S, rule="gol")
    jax.block_until_ready(r)
    per_sub = fused_items_per_launch(M, T, g, S) / S
    out.append((f"kernel/stencil_fused_S{S}_interpret_{kind}",
                (time.perf_counter() - t0) / 3 / S * 1e6,
                f"T={T};g={g};nb={nb};S={S};fields=1"
                f";hbm_items_per_substep={per_sub:.0f}"))

    # multi-field wave (C=2, DESIGN.md §9): same fused launch over the
    # stacked store — one grid step streams two windows, writes two tiles
    wstore = jnp.stack([store, jnp.zeros_like(store)])
    stencil_step_fused(wstore, gw, nbr, g=g, S=S, rule="wave")  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        r = stencil_step_fused(wstore, gw, nbr, g=g, S=S, rule="wave")
    jax.block_until_ready(r)
    per_sub2 = fused_items_per_launch(M, T, g, S, fields=2) / S
    out.append((f"kernel/stencil_fused_wave_S{S}_interpret_{kind}",
                (time.perf_counter() - t0) / 3 / S * 1e6,
                f"T={T};g={g};nb={nb};S={S};fields=2"
                f";hbm_items_per_substep={per_sub2:.0f}"))
    return out


def rows():
    return (attention_schedule_rows() + stencil_block_rows()
            + interpret_timing_rows() + resident_kernel_rows())
