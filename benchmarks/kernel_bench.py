"""Pallas kernel benchmarks: schedule-locality scoring + interpret timing.

The real object here is structural (this container has no TPU): the
paper's LRU cache model (core/cache_model.simulate_lru) re-parameterised
for VMEM scores the *block fetch stream* of each flash-attention
schedule — row-major vs Morton vs Hilbert traversal of the (q,kv) block
grid. A "line" is one block; capacity c is how many blocks fit VMEM.
Fewer misses = fewer HBM→VMEM DMAs = lower memory term on TPU.

Also times the interpret-mode kernels (CPU correctness path) so
regressions are visible.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_model import simulate_lru
from repro.kernels.flash_attn import build_schedule, flash_attention_fwd
from repro.kernels.stencil3d import stencil_sum_blocks
from repro.core.layout import block_order


def _attention_block_stream(nq, nk, kind, causal=True):
    """Sequence of distinct (kind, block) VMEM fetches for a schedule."""
    iq, ik = build_schedule(nq, nk, causal=causal, block_q=1, block_k=1,
                            kind=kind)
    stream = []
    for a, b in zip(iq.tolist(), ik.tolist()):
        stream.append(("q", a))
        stream.append(("k", b))
        stream.append(("v", b))
    ids = {}
    return np.array([ids.setdefault(s, len(ids)) for s in stream])


def attention_schedule_rows(nq: int = 32, nk: int = 32, vmem_blocks: int = 24):
    out = []
    for kind in ("row_major", "morton", "hilbert"):
        t0 = time.perf_counter()
        stream = _attention_block_stream(nq, nk, kind)
        misses = simulate_lru(stream, vmem_blocks)
        dt = (time.perf_counter() - t0) * 1e6
        hbm_refetch = misses / (nq + 2 * nk)  # 1.0 = each block fetched once
        out.append((f"kernel/flash_sched_{kind}_nq{nq}", dt,
                    f"vmem_misses={misses};refetch_factor={hbm_refetch:.2f}"))
    return out


def stencil_block_rows(nt: int = 8, vmem_blocks: int = 8):
    """Stencil block walk: consecutive blocks share halos; the LRU model
    counts how often a neighbour block is still VMEM-resident."""
    out = []
    for kind in ("row_major", "morton", "hilbert"):
        t0 = time.perf_counter()
        bo = block_order(kind, nt)
        # stream: each step touches the block and its -x/-y/-z face
        # neighbours (already-produced halo data reused if resident)
        lin = bo[:, 0] * nt * nt + bo[:, 1] * nt + bo[:, 2]
        stream = []
        for t in range(nt ** 3):
            k, i, j = bo[t]
            stream.append(int(lin[t]))
            for dk, di, dj in ((-1, 0, 0), (0, -1, 0), (0, 0, -1)):
                nk_, ni, nj = (k + dk) % nt, (i + di) % nt, (j + dj) % nt
                stream.append(int(nk_ * nt * nt + ni * nt + nj))
        misses = simulate_lru(np.asarray(stream), vmem_blocks)
        dt = (time.perf_counter() - t0) * 1e6
        out.append((f"kernel/stencil_walk_{kind}_nt{nt}", dt,
                    f"vmem_misses={misses};min_possible={nt**3}"))
    return out


def interpret_timing_rows():
    rng = np.random.default_rng(0)
    out = []
    # stencil kernel
    blocks = jnp.asarray(rng.normal(size=(8, 10, 10, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3)).astype(np.float32))
    stencil_sum_blocks(blocks, w, g=1)  # compile
    t0 = time.perf_counter()
    for _ in range(5):
        r = stencil_sum_blocks(blocks, w, g=1)
    jax.block_until_ready(r)
    out.append(("kernel/stencil3d_interpret", (time.perf_counter() - t0) / 5 * 1e6,
                "T=8;g=1;nb=8"))
    # flash attention kernel
    q = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
    flash_attention_fwd(q, q, q, causal=True, block_q=32, block_k=32)
    t0 = time.perf_counter()
    for _ in range(5):
        r = flash_attention_fwd(q, q, q, causal=True, block_q=32, block_k=32)
    jax.block_until_ready(r)
    out.append(("kernel/flash_attn_interpret", (time.perf_counter() - t0) / 5 * 1e6,
                "S=128;D=32;morton"))
    return out


def rows():
    return (attention_schedule_rows() + stencil_block_rows()
            + interpret_timing_rows())
