"""Paper Figs 11 / 15: time to pack the six surfaces into buffers.

Packs from the ordering's path-ordered storage via the precomputed index
lists (the paper's mechanism), for halo widths {1, 2} and M ∈ {32, 64}.
Also reports the structural metric behind the timings: DMA-run count
(contiguous runs per face) — the TPU-side cost model, where each run is
one descriptor for kernels/sfc_gather.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HILBERT, MORTON, ROW_MAJOR, apply_ordering
from repro.core.surfaces import PAPER_SURFACE_NAMES, run_stats
from repro.kernels.ops import pack_surface

FACE_GROUPS = (("k0", "k1"), ("i0", "i1"), ("j0", "j1"))
N_REPS = 20


def rows(sizes=(32, 64), widths=(1, 2)):
    out = []
    rng = np.random.default_rng(0)
    for M in sizes:
        cube = jnp.asarray(rng.random((M, M, M)).astype(np.float32))
        for g in widths:
            for spec in (ROW_MAJOR, MORTON, HILBERT):
                data = apply_ordering(cube, spec)

                @jax.jit
                def pack_all(d, spec=spec, M=M, g=g):
                    return [pack_surface(d, spec, M, g, f)
                            for pair in FACE_GROUPS for f in pair]

                jax.block_until_ready(pack_all(data))  # compile
                t0 = time.perf_counter()
                for _ in range(N_REPS):
                    bufs = pack_all(data)
                jax.block_until_ready(bufs)
                dt = (time.perf_counter() - t0) / N_REPS
                runs = {PAPER_SURFACE_NAMES[f]: run_stats(spec, M, g, f).n_runs
                        for pair in FACE_GROUPS for f in pair}
                out.append((f"fig11_15/pack_M{M}_g{g}_{spec.name}", dt * 1e6,
                            "dma_runs=" + ",".join(f"{k}:{v}"
                                                   for k, v in runs.items())))
    return out
