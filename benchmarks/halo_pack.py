"""Paper Figs 11 / 15: time to pack the six surfaces into buffers.

Packs from the ordering's path-ordered storage via the precomputed index
lists (the paper's mechanism), for halo widths {1, 2} and M ∈ {32, 64}.
Also reports the structural metric behind the timings: DMA-run count
(contiguous runs per face) — the TPU-side cost model, where each run is
one descriptor for kernels/sfc_gather.py.

The ``exchange/`` rows sweep the *deep* exchange depth h = S·g of the
communication-avoiding distributed pipeline (DESIGN.md §7): six width-h
faces packed straight from the resident block store (the hybrid
store_spec ordering), with the modelled ICI bytes per exchange and per
*timestep* from the shared accounting helpers — so the perf trajectory
carries network traffic alongside the HBM numbers.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HILBERT, MORTON, NEUMANN0, ROW_MAJOR, apply_ordering,
                        blockize)
from repro.core.layout import store_spec
from repro.core.surfaces import PAPER_SURFACE_NAMES, run_stats
from repro.kernels.ops import pack_surface
from repro.stencil import exchange_bytes_per_step, exchange_items_per_exchange

FACE_GROUPS = (("k0", "k1"), ("i0", "i1"), ("j0", "j1"))
N_REPS = 20


def rows(sizes=(32, 64), widths=(1, 2)):
    out = []
    rng = np.random.default_rng(0)
    for M in sizes:
        cube = jnp.asarray(rng.random((M, M, M)).astype(np.float32))
        for g in widths:
            for spec in (ROW_MAJOR, MORTON, HILBERT):
                data = apply_ordering(cube, spec)

                @jax.jit
                def pack_all(d, spec=spec, M=M, g=g):
                    return [pack_surface(d, spec, M, g, f)
                            for pair in FACE_GROUPS for f in pair]

                jax.block_until_ready(pack_all(data))  # compile
                t0 = time.perf_counter()
                for _ in range(N_REPS):
                    bufs = pack_all(data)
                jax.block_until_ready(bufs)
                dt = (time.perf_counter() - t0) / N_REPS
                runs = {PAPER_SURFACE_NAMES[f]: run_stats(spec, M, g, f).n_runs
                        for pair in FACE_GROUPS for f in pair}
                out.append((f"fig11_15/pack_M{M}_g{g}_{spec.name}", dt * 1e6,
                            "dma_runs=" + ",".join(f"{k}:{v}"
                                                   for k, v in runs.items())))
    out += deep_rows(sizes=sizes)
    out += clamped_exchange_rows(sizes=sizes)
    return out


def deep_rows(sizes=(32, 64), depths=(1, 2, 4), g=1, T=8):
    """Deep-exchange pack sweep: six width-S·g faces from the block store.

    Times the in-store pack the distributed pipeline runs once per S
    substeps; ``derived`` carries the modelled ICI traffic
    (exchange_items/bytes helpers — the same single accounting the
    stencil_update rows and DistributedPipeline.plan() use). Bytes per
    exchange grow with S (the corner terms), bytes per *step* stay
    nearly flat — the win is exchange frequency and HBM amortisation.
    """
    out = []
    rng = np.random.default_rng(1)
    for M in sizes:
        cube = jnp.asarray(rng.random((M, M, M)).astype(np.float32))
        for kind in ("morton", "hilbert"):
            hspec = store_spec(kind, T)
            store = blockize(cube, T, kind=kind).reshape(-1)
            for S in depths:
                h = S * g
                if h > T or T % h:
                    continue

                @jax.jit
                def pack_all(d, hspec=hspec, M=M, h=h):
                    return [pack_surface(d, hspec, M, h, f)
                            for pair in FACE_GROUPS for f in pair]

                jax.block_until_ready(pack_all(store))  # compile
                t0 = time.perf_counter()
                for _ in range(N_REPS):
                    bufs = pack_all(store)
                jax.block_until_ready(bufs)
                dt = (time.perf_counter() - t0) / N_REPS
                out.append((
                    f"exchange/deep_pack_M{M}_g{g}_S{S}_{kind}", dt * 1e6,
                    f"h={h}"
                    f";ici_bytes_per_exchange="
                    f"{4 * exchange_items_per_exchange(M, g, S):.0f}"
                    f";ici_bytes_per_step={exchange_bytes_per_step(M, g, S):.0f}",
                ))
    return out


def clamped_exchange_rows(sizes=(32, 64), depths=(1, 4), g=1, T=8,
                          procs=(2, 2, 2)):
    """Clamped exchange surface (DESIGN.md §8): mesh-edge shards skip the
    wrap links, so they pack the same six faces (the packs also feed the
    boundary fill) but *send* fewer. Timing is the six-face in-store
    pack (identical work to the periodic row — the saving is wire-only);
    ``derived`` carries the per-shard clamped ICI model: torus vs mesh
    mean vs corner shard, from the one accounting helper set.
    """
    out = []
    rng = np.random.default_rng(2)
    for M in sizes:
        cube = jnp.asarray(rng.random((M, M, M)).astype(np.float32))
        for kind in ("morton", "hilbert"):
            hspec = store_spec(kind, T)
            store = blockize(cube, T, kind=kind).reshape(-1)
            for S in depths:
                h = S * g
                if h > T or T % h:
                    continue

                @jax.jit
                def pack_all(d, hspec=hspec, M=M, h=h):
                    return [pack_surface(d, hspec, M, h, f)
                            for pair in FACE_GROUPS for f in pair]

                jax.block_until_ready(pack_all(store))  # compile
                t0 = time.perf_counter()
                for _ in range(N_REPS):
                    bufs = pack_all(store)
                jax.block_until_ready(bufs)
                dt = (time.perf_counter() - t0) / N_REPS
                per = 4 * exchange_items_per_exchange(M, g, S)
                mean = 4 * exchange_items_per_exchange(
                    M, g, S, bc=NEUMANN0, procs=procs)
                corner = 4 * exchange_items_per_exchange(
                    M, g, S, bc=NEUMANN0, procs=procs, coords=(0, 0, 0))
                out.append((
                    f"exchange/clamped_M{M}_g{g}_S{S}_{kind}", dt * 1e6,
                    f"h={h};bc=neumann0"
                    f";ici_bytes_per_exchange_periodic={per:.0f}"
                    f";ici_bytes_per_exchange_clamped={mean:.0f}"
                    f";ici_bytes_per_exchange_edge_shard={corner:.0f}"
                    f";ici_clamped_vs_periodic={mean / per:.3f}",
                ))
    return out
