"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  offset_hist     — Figs 5-7  (offset histograms)
  cache_misses    — Figs 16-20 (surface miss counts, model)
  stencil_update  — Figs 8-10/12-14 (update timings) + repack-vs-resident
  halo_pack       — Figs 11/15 (pack timings + DMA runs)
  kernel_bench    — Pallas schedules scored by the paper's LRU model
  roofline_table  — §Roofline rows from the dry-run artefacts

Flags:
  --fast          smaller sizes (CI-friendly)
  --json PATH     additionally write the rows as a JSON list of
                  {"name", "us_per_call", "derived": {k: v}} objects —
                  the machine-readable form the perf trajectory tracking
                  consumes (derived "k=v;k=v" strings are split; numeric
                  values are parsed).
"""

from __future__ import annotations

import json
import sys


def _parse_derived(derived: str) -> dict:
    out: dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            if part:
                out[part] = True
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def collect(fast: bool = False) -> list[tuple[str, float, str]]:
    from . import (cache_misses, halo_pack, kernel_bench, offset_hist,
                   roofline_table, stencil_update)

    sections = [
        offset_hist.rows(),
        cache_misses.rows(M=32 if fast else 64),
        stencil_update.rows(sizes=(32,) if fast else (32, 64),
                            stencils=(1,) if fast else (1, 2)),
        halo_pack.rows(sizes=(32,) if fast else (32, 64),
                       widths=(1,) if fast else (1, 2)),
        kernel_bench.rows(),
        roofline_table.rows(),
    ]
    return [row for rows in sections for row in rows]


def main() -> None:
    fast = "--fast" in sys.argv
    json_path = None
    if "--json" in sys.argv:
        i = sys.argv.index("--json")
        if i + 1 >= len(sys.argv):
            raise SystemExit("--json needs a path argument")
        json_path = sys.argv[i + 1]

    rows = collect(fast=fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if json_path:
        payload = [{"name": name, "us_per_call": round(us, 1),
                    "derived": _parse_derived(derived)}
                   for name, us, derived in rows]
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload)} rows to {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
