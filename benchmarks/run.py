"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  offset_hist     — Figs 5-7  (offset histograms)
  cache_misses    — Figs 16-20 (surface miss counts, model)
  stencil_update  — Figs 8-10/12-14 (update timings) + repack-vs-resident
  halo_pack       — Figs 11/15 (pack timings + DMA runs)
  kernel_bench    — Pallas schedules scored by the paper's LRU model
  roofline_table  — §Roofline rows from the dry-run artefacts
  roi             — ROI-query serving rows (range counts, bytes read)

Flags:
  --fast          smaller sizes (CI-friendly)
  --json PATH     additionally write {"git_rev": ..., "rows": [...]} where
                  rows is a list of {"name", "us_per_call", "fields",
                  "derived": {k: v}} objects — the machine-readable form
                  the perf trajectory tracking consumes (derived
                  "k=v;k=v" strings are split; numeric values are parsed;
                  git_rev stamps which revision produced the numbers;
                  "fields" is the row's channel count C, defaulting to 1
                  for rows that predate the multi-field store — the
                  schema dimension the modelled-bytes keys are pinned
                  under, DESIGN.md §9).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys


def _parse_derived(derived: str) -> dict:
    out: dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            if part:
                out[part] = True
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def git_rev() -> str:
    """Short rev of the benchmarked tree (``unknown`` outside a checkout)."""
    try:
        r = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        rev = r.stdout.strip()
        return rev if r.returncode == 0 and rev else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def collect(fast: bool = False) -> list[tuple[str, float, str]]:
    from . import (cache_misses, halo_pack, kernel_bench, offset_hist,
                   roi, roofline_table, stencil_update)

    sections = [
        offset_hist.rows(),
        cache_misses.rows(M=32 if fast else 64),
        stencil_update.rows(sizes=(32,) if fast else (32, 64),
                            stencils=(1,) if fast else (1, 2)),
        halo_pack.rows(sizes=(32,) if fast else (32, 64),
                       widths=(1,) if fast else (1, 2)),
        kernel_bench.rows(),
        roofline_table.rows(),
        roi.rows(sizes=(32,) if fast else (32, 64)),
    ]
    return [row for rows in sections for row in rows]


def main() -> None:
    fast = "--fast" in sys.argv
    json_path = None
    if "--json" in sys.argv:
        i = sys.argv.index("--json")
        if i + 1 >= len(sys.argv):
            raise SystemExit("--json needs a path argument")
        json_path = sys.argv[i + 1]

    rows = collect(fast=fast)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if json_path:
        def _row(name, us, derived):
            d = _parse_derived(derived)
            return {"name": name, "us_per_call": round(us, 1),
                    "fields": int(d.get("fields", 1)), "derived": d}

        payload = {
            "git_rev": git_rev(),
            "rows": [_row(name, us, derived) for name, us, derived in rows],
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload['rows'])} rows "
              f"(rev {payload['git_rev']}) to {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
