"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  offset_hist     — Figs 5-7  (offset histograms)
  cache_misses    — Figs 16-20 (surface miss counts, model)
  stencil_update  — Figs 8-10/12-14 (update timings)
  halo_pack       — Figs 11/15 (pack timings + DMA runs)
  kernel_bench    — Pallas schedules scored by the paper's LRU model
  roofline_table  — §Roofline rows from the dry-run artefacts
"""

from __future__ import annotations

import sys


def main() -> None:
    from . import (cache_misses, halo_pack, kernel_bench, offset_hist,
                   roofline_table, stencil_update)

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    sections = [
        offset_hist.rows(),
        cache_misses.rows(M=32 if fast else 64),
        stencil_update.rows(sizes=(32,) if fast else (32, 64),
                            stencils=(1,) if fast else (1, 2)),
        halo_pack.rows(sizes=(32,) if fast else (32, 64),
                       widths=(1,) if fast else (1, 2)),
        kernel_bench.rows(),
        roofline_table.rows(),
    ]
    for rows in sections:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
