"""§Roofline: aggregate the dry-run JSON records into the per-cell table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch × shape × mesh): the three roofline terms, the
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the MFU bound.
"""

from __future__ import annotations

import glob
import json
import os

# final (optimized) build artefacts; experiments/dryrun holds the
# original baseline records for the §Perf before/after comparison.
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun_final")
if not os.path.isdir(DRYRUN_DIR):  # fall back to baseline records
    DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                              "dryrun")


def load_records(tag: str | None = None):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        name = os.path.splitext(os.path.basename(p))[0]
        is_tagged = "#" in name
        if tag is None and is_tagged:
            continue
        if tag is not None and not name.endswith(tag):
            continue
        recs.append(r)
    return recs


def rows():
    out = []
    for r in load_records():
        name = f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}"
        t_us = r["t_bound_s"] * 1e6
        out.append((name, t_us,
                    f"bottleneck={r['bottleneck']};"
                    f"t_comp_ms={r['t_compute_s']*1e3:.2f};"
                    f"t_mem_ms={r['t_memory_s']*1e3:.2f};"
                    f"t_coll_ms={r['t_collective_s']*1e3:.2f};"
                    f"useful={r['useful_flops_frac']:.2f};"
                    f"mfu_bound={r['mfu_bound']:.3f}"))
    return out


def markdown_table(recs=None) -> str:
    recs = recs if recs is not None else load_records()
    lines = ["| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
             "bottleneck | useful | MFU-bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh'].split('_')[0]} | "
            f"{r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} | "
            f"{r['t_collective_s']*1e3:.2f} | {r['bottleneck']} | "
            f"{r['useful_flops_frac']:.2f} | {r['mfu_bound']:.2%} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
