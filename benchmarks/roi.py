"""ROI-query locality rows: the paper's claim restated as a serving win.

For each ordering × aligned-ROI pair over an M³/T=8 block store, time the
block-sparse extraction (serve/roi.extract_roi) and stamp the
deterministic model (serve/roi.roi_model, DESIGN.md §11): contiguous
curve-range count, blocks touched, bytes read, payload bytes, and
utilization. ``blocks``/``bytes_read``/``utilization`` are
curve-independent (the block box is geometry); ``ranges`` is the
locality signal — the number of separate contiguous reads a storage
tier must issue. The ROI suite is aligned power-of-two boxes, where
hilbert/morton collapse whole octree subtrees into single ranges:
hilbert is strictly below row-major on every row (asserted in
tests/test_serve_roi.py, pinned exactly in CI via
``benchmarks/diff.py --keys-threshold 0``).
"""

from __future__ import annotations

import time

import numpy as np

from repro.serve import ROI, StoreLayout, extract_roi, roi_model

N_ITERS = 20
ORDERINGS = ("row_major", "column_major", "morton", "hilbert")


def roi_suite(M: int) -> list[tuple[str, ROI]]:
    """The benchmarked ROI suite: aligned power-of-two boxes (the regime
    where curve choice moves the range count — an aligned 2^a block cube
    is one octree subtree = one range on any bit-hierarchical curve)
    plus one unaligned ``viewport`` (the exemplar repo's map-client
    case, where utilization drops below 1 because edge blocks carry
    waste). Every entry has range-count(hilbert) strictly below
    range-count(row_major) at T=8 for M ∈ {32, 64} — the acceptance
    contract tests/test_serve_roi.py asserts row by row."""
    h = M // 2
    return [
        ("octant", ROI((0, 0, 0), (h, h, h))),
        ("octant_hi", ROI((h, h, h), (M, M, M))),
        ("slab", ROI((0, 0, 0), (M, h, h))),
        ("tile", ROI((0, h, 0), (h, M, h))),
        ("viewport", ROI((3, 5, 2), (h + 3, h + 5, h + 2))),
    ]


def rows(sizes=(32, 64), T: int = 8):
    out = []
    rng = np.random.default_rng(0)
    for M in sizes:
        nb = (M // T) ** 3
        store_flat = rng.standard_normal((nb, T, T, T)).astype(np.float32)
        for kind in ORDERINGS:
            layout = StoreLayout(M=M, T=T, kind=kind)
            for roi_name, roi in roi_suite(M):
                m = roi_model(layout, roi)
                # warm then time the block-sparse decode
                extract_roi(store_flat, layout, roi)
                t0 = time.perf_counter()
                for _ in range(N_ITERS):
                    extract_roi(store_flat, layout, roi)
                dt = time.perf_counter() - t0
                derived = (f"roi_ranges={m['ranges']};"
                           f"roi_blocks={m['blocks_touched']};"
                           f"roi_bytes_read={m['bytes_read']};"
                           f"roi_payload_bytes={m['payload_bytes']};"
                           f"utilization={m['utilization']:.4f};"
                           f"fields=1")
                out.append((f"roi/extract_M{M}_T{T}_{kind}_{roi_name}",
                            dt * 1e6 / N_ITERS, derived))
    return out


if __name__ == "__main__":
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")
