"""Paper Figs 16–20: cache misses when buffering each surface.

The paper reads hardware counters (perf_event) on EPYC/Xeon; this host has
neither, so the numbers come from the paper's own cache model (Alg. 1,
§3.2 surface variant) — the model the paper uses to *explain* those
figures. Parameters model an L1-like cache: 64-item lines (b) × 512 lines
(c). The signature result must match Figs 11/16: row-major sr faces miss
orders of magnitude more; SFC faces are uniform.
"""

from __future__ import annotations

import time

from repro.core import HILBERT, MORTON, ROW_MAJOR, surface_cache_misses
from repro.core.surfaces import PAPER_SURFACE_NAMES


def rows(M: int = 64, g: int = 1, b: int = 64, c: int = 512):
    out = []
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        for face in ("k0", "k1", "i0", "i1", "j0", "j1"):
            t0 = time.perf_counter()
            m = surface_cache_misses(spec, M, g, b, c, face)
            dt = (time.perf_counter() - t0) * 1e6
            out.append((
                f"fig16_19/misses_M{M}_{spec.name}_{PAPER_SURFACE_NAMES[face]}",
                dt, f"misses={m}"))
    return out
