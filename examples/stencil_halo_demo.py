"""Distributed gol3d: 2×2×2 device mesh, SFC halo packing, ppermute rings.

Part 1 (parent process): the resident-block pipeline — blockize once,
run K steps entirely in curve order with in-kernel halo streaming and
S-deep temporal blocking (stencil/pipeline.py; S substeps per HBM
round-trip), verify bit-identity against the per-step repack pipeline,
and print the modelled per-substep HBM bytes of repack / unfused /
fused forms plus the (T, S) the plan() autotuner picks.

Part 2: spawns itself with 8 host devices (the dry-run rule: never force
device count in the parent process), decomposes a 32³ cube onto the
mesh, and runs 10 steps under each ordering two ways: the legacy
per-step exchange (make_distributed_step) verified against the
single-device oracle, and the communication-avoiding DistributedPipeline
(one deep S·g exchange per S fused substeps, DESIGN.md §7) verified
bit-identical to the per-step form. This is the paper's parallel
experiment (§4, second set) as a shard_map program. The same matrix
then repeats under clamped neumann0 boundaries (DESIGN.md §8) — open
exchange rings, shell-block boundary fill — and the modelled ICI
savings table prints for both boundary contracts (mesh-edge shards
skip the wrap links, so clamped shards move strictly fewer wire bytes).

Part 3 (parent process): the multi-field store (DESIGN.md §9) — the
C=2 FDTD-style wave rule rides the same fused resident pipeline at
S ∈ {2, 4}, bit-identical to its sequential global oracle
(kernels/ref.fields_step_ref), and the ×C bytes-model table prints the
2-field stream next to the PR 2/3 single-field numbers: HBM and ICI
both scale by exactly C, never more.

Run: PYTHONPATH=src python examples/stencil_halo_demo.py
(docs/quickstart.md walks through the output.)
"""

import os
import subprocess
import sys


def resident_demo(M=32, g=1, T=8, steps=10, S=4):
    import time

    import numpy as np
    import jax

    from repro.core import HILBERT, MORTON
    from repro.stencil import (Gol3d, Gol3dConfig, ResidentPipeline,
                               repack_bytes_per_step, resident_bytes_per_step,
                               resident_unfused_bytes_per_step)

    print(f"[stencil_halo_demo] resident pipeline, M={M} g={g} T={T} "
          f"K={steps} steps, temporal blocking S={S}")
    rep_b = repack_bytes_per_step(M, T, g)
    unf_b = resident_unfused_bytes_per_step(M, T, g, steps)
    fus_b = resident_bytes_per_step(M, T, g, steps, S=S)
    print(f"  modelled HBM bytes/substep: repack={rep_b / 1e6:.2f} MB  "
          f"resident(unfused)={unf_b / 1e6:.2f} MB  "
          f"fused S={S}={fus_b / 1e6:.2f} MB  "
          f"(x{rep_b / fus_b:.2f} vs repack, x{unf_b / fus_b:.2f} vs unfused)")
    auto = ResidentPipeline.plan(M, g=g)
    print(f"  plan(M={M}, g={g}) -> T={auto.T} S={auto.S} "
          f"(vmem {auto.vmem_bytes() / 1024:.0f} KiB, "
          f"{auto.bytes_per_step(steps) / 1e6:.2f} MB/substep)")
    for spec in (MORTON, HILBERT):
        app = Gol3d(Gol3dConfig(M=M, g=g, ordering=spec, block_T=T,
                                substeps=S))
        # repack: warm the per-step jit, then time K steps
        step = app.step_fn()
        jax.block_until_ready(step(app.state_path))
        t0 = time.perf_counter()
        s = app.state_path
        for _ in range(steps):
            s = step(s)
        sa = jax.block_until_ready(s)
        t_rep = time.perf_counter() - t0
        # fused resident: ceil(K/S) launches over the persistent store
        pipe = app.resident_pipeline()
        run = pipe.run_fn(steps)
        jax.block_until_ready(run(pipe.to_blocks(app.cube)))
        t0 = time.perf_counter()
        out = jax.block_until_ready(run(pipe.to_blocks(app.cube)))
        t_res = time.perf_counter() - t0
        from repro.core import apply_ordering
        sb = apply_ordering(pipe.to_cube(out), spec)
        ok = np.array_equal(np.asarray(sa), np.asarray(sb))
        print(f"  {spec.name:10s} repack {t_rep * 1e3 / steps:6.1f} ms/step  "
              f"fused S={pipe.S} {t_res * 1e3 / steps:6.1f} ms/step  "
              f"bit-identical: {ok}")
        assert ok
    print("resident pipeline OK")

def wave_demo(M=32, g=1, T=8, steps=8):
    """Part 3: the C=2 wave workload on the multi-field block store."""
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref as kref
    from repro.kernels.ops import uniform_weights
    from repro.stencil import (ResidentPipeline, distributed_bytes_per_step,
                               exchange_bytes_per_step,
                               resident_bytes_per_step)

    C = 2
    print(f"[stencil_halo_demo] multi-field wave (C={C}), M={M} g={g} T={T} "
          f"K={steps} steps")
    rng = np.random.default_rng(0)
    fields = jnp.asarray(rng.normal(size=(C, M, M, M)).astype(np.float32))
    w = uniform_weights(g)
    want = fields
    for _ in range(steps):
        want = kref.fields_step_ref(want, w, g, rule="wave")
    want = np.asarray(want)
    for S in (2, 4):
        pipe = ResidentPipeline(M=M, T=T, g=g, kind="hilbert", S=S,
                                rule="wave")
        run = pipe.run_fn(steps)
        jax.block_until_ready(run(pipe.to_blocks(fields)))  # warm
        store = pipe.to_blocks(fields)
        t0 = time.perf_counter()
        out = jax.block_until_ready(run(store))
        dt = time.perf_counter() - t0
        ok = np.array_equal(np.asarray(pipe.to_cube(out)), want)
        print(f"  wave fused S={S}: {dt * 1e3 / steps:6.1f} ms/step  "
              f"bit-identical to sequential oracle: {ok}")
        assert ok
    # the xC bytes model next to the PR 2/3 single-field numbers
    print(f"  modelled bytes/substep (M={M}, T={T}, g={g}): "
          "single-field vs C=2")
    print("    S   HBM C=1     HBM C=2     ICI C=1     ICI C=2    ratio")
    for S in (1, 2, 4):
        h1 = resident_bytes_per_step(M, T, g, steps, S=S)
        h2 = resident_bytes_per_step(M, T, g, steps, S=S, fields=C)
        i1 = exchange_bytes_per_step(M, g, S)
        i2 = exchange_bytes_per_step(M, g, S, fields=C)
        d2 = distributed_bytes_per_step(M, T, g, steps, S=S, fields=C)
        print(f"    {S}  {h1 / 1e6:7.2f} MB {h2 / 1e6:8.2f} MB "
              f"{i1 / 1e3:8.1f} KB {i2 / 1e3:8.1f} KB   x{h2 / h1:.2f} "
              f"(dist C=2 {d2 / 1e6:.2f} MB)")
    print("multi-field wave OK")


_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import ROW_MAJOR, MORTON, HILBERT, NEUMANN0, PERIODIC
from repro.stencil import (make_stencil_mesh, make_distributed_step,
                           DistributedPipeline, shard_state, unshard_state,
                           distributed_bytes_per_step, exchange_bytes_per_step)
from repro.kernels import ref as kref

mesh = make_stencil_mesh((2, 2, 2))
procs = (2, 2, 2)
local_M, g, GM, steps = 16, 1, 32, 10
rng = np.random.default_rng(0)
gcube = (rng.random((GM, GM, GM)) < 0.35).astype(np.float32)

sharding = NamedSharding(mesh, P("dx", "dy", "dz"))
for bc in (PERIODIC, NEUMANN0):
    print(f"  --- boundaries: {bc.kind} ---")
    want = jnp.asarray(gcube)
    for _ in range(steps):
        want = kref.gol3d_step_ref(want, g, bc=bc)
    want = np.asarray(want)
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        st = jax.device_put(shard_state(jnp.asarray(gcube), spec, (2, 2, 2)),
                            sharding)
        # legacy reference: one exchange per step (S=1)
        step = make_distributed_step(mesh, spec, local_M, g, bc=bc)
        jax.block_until_ready(step(st))  # compile
        t0 = time.perf_counter()
        gs = st
        for _ in range(steps):
            gs = step(gs)
        out_seq = np.asarray(jax.block_until_ready(gs))
        dt_seq = (time.perf_counter() - t0) / steps
        ok = np.array_equal(np.asarray(unshard_state(jnp.asarray(out_seq), spec, GM)), want)
        line = f"  {spec.name:10s} per-step {dt_seq*1e3:6.1f} ms/step (oracle: {ok})"
        assert ok
        # communication-avoiding pipeline: one deep exchange per S substeps
        for S in (2, 4):
            pipe = DistributedPipeline(mesh=mesh, spec=spec, M=local_M, T=8,
                                       g=g, S=S, bc=bc)
            run = pipe.run_fn(steps)
            jax.block_until_ready(run(st))  # compile
            t0 = time.perf_counter()
            out = np.asarray(jax.block_until_ready(run(st)))
            dt = (time.perf_counter() - t0) / steps
            okS = np.array_equal(out, out_seq)  # bit-identical to S=1 reference
            line += f"  S={S} {dt*1e3:6.1f} ms/step (bit-identical: {okS})"
            assert okS
        print(line)

# modelled ICI savings per mesh shard: deep exchange (S) x boundary contract.
# Periodic torus shards send both faces on every axis; clamped mesh-edge
# shards skip the wrap links (DESIGN.md §8) - on a 2x2x2 mesh every shard
# is a corner, so the clamped column is exactly half the torus volume.
print("  modelled ICI bytes/step/shard (local M=16, g=1):")
print("    S   periodic   clamped(mean)   edge-shard   clamped/periodic")
for S in (1, 2, 4):
    per = exchange_bytes_per_step(local_M, g, S)
    mean = exchange_bytes_per_step(local_M, g, S, bc=NEUMANN0, procs=procs)
    edge = exchange_bytes_per_step(local_M, g, S, bc=NEUMANN0, procs=procs,
                                   coords=(0, 0, 0))
    print(f"    {S}   {per/1e3:7.1f} KB {mean/1e3:10.1f} KB "
          f"{edge/1e3:9.1f} KB   x{mean/per:.2f}")
b1 = distributed_bytes_per_step(local_M, 8, g, steps, S=1)
b4 = distributed_bytes_per_step(local_M, 8, g, steps, S=4)
b4c = distributed_bytes_per_step(local_M, 8, g, steps, S=4, bc=NEUMANN0,
                                 procs=procs)
print(f"  modelled bytes/step/shard (HBM+ICI): S=1 {b1/1e3:.0f} KB -> "
      f"S=4 {b4/1e3:.0f} KB (x{b1/b4:.2f}); clamped S=4 {b4c/1e3:.0f} KB")
print("distributed gol3d OK (periodic + clamped)")
"""


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    sys.path.insert(0, env["PYTHONPATH"])
    resident_demo()
    wave_demo()
    print("[stencil_halo_demo] launching 8-device subprocess...")
    r = subprocess.run([sys.executable, "-c", _WORKER], env=env)
    raise SystemExit(r.returncode)


if __name__ == "__main__":
    main()
