"""Quickstart: the paper's pipeline in five minutes.

1. Build Morton/Hilbert orderings of a data cube.
2. Reproduce the paper's offset histogram + cache-model results.
3. Run gol3d under each ordering and check they agree.
4. Pack halo surfaces from SFC storage (the paper's §3.2 experiment).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (HILBERT, MORTON, ROW_MAJOR, apply_ordering,
                        cache_misses, offset_summary, surface_cache_misses)
from repro.core.surfaces import PAPER_SURFACE_NAMES, run_stats
from repro.kernels.ops import pack_surface
from repro.stencil import Gol3d, Gol3dConfig


def main():
    M, g = 32, 1
    print("== 1. offset histograms (paper Figs 5-7) ==")
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        s = offset_summary(spec, M, g)
        print(f"  {spec.name:10s} distinct offsets {s.n_distinct:6d}  "
              f"within-64-line fraction {s.frac_within_line:.3f}")

    print("== 2. cache model (Alg. 1) ==")
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        m = cache_misses(spec, M, g, b=8, c=64)
        sr = surface_cache_misses(spec, M, g, 8, 64, "j0")
        print(f"  {spec.name:10s} interior misses {m:7d}   sr-face misses {sr:5d}")

    print("== 3. gol3d under the three orderings (results must agree) ==")
    finals = {}
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        app = Gol3d(Gol3dConfig(M=16, g=1, ordering=spec, block_T=4, seed=1))
        app.run(5)
        finals[spec.name] = np.asarray(app.cube)
    ref = finals["row_major"]
    for k, v in finals.items():
        ok = np.array_equal(ref, v)
        print(f"  {k:10s} matches row-major result: {ok}")
        assert ok

    print("== 4. surface packing from SFC storage (paper §3.2) ==")
    rng = np.random.default_rng(0)
    cube = jnp.asarray(rng.random((M, M, M)).astype(np.float32))
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        data = apply_ordering(cube, spec)
        buf = pack_surface(data, spec, M, g, "j0")
        rs = run_stats(spec, M, g, "j0")
        print(f"  {spec.name:10s} packed {buf.shape[0]:5d} items of the "
              f"{PAPER_SURFACE_NAMES['j0']} face in {rs.n_runs:4d} contiguous "
              f"runs (mean run {rs.mean_run:.1f})")
    print("done.")


if __name__ == "__main__":
    main()
