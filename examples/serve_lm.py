"""Batched serving demo: prefill + greedy decode over concurrent requests.

Builds a reduced gemma3-family model (sliding-window + global layers —
the long-context serving case), loads a batch of prompts, and decodes
new tokens for all requests in lockstep with a preallocated KV cache
(the shape-stable regime a continuous-batching server runs in).

Run: PYTHONPATH=src python examples/serve_lm.py [--new-tokens 32]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("gemma3-1b"), n_layers=6, d_model=256, n_heads=4,
        n_kv_heads=1, head_dim=64, d_ff=512, vocab=4096, sliding_window=32,
        global_every=3, activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve_lm] {model.n_params()/1e6:.1f}M-param gemma3-family model, "
          f"{args.batch} concurrent requests")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), np.int32))
    max_len = args.prompt_len + args.new_tokens + 1
    t0 = time.perf_counter()
    out = greedy_decode(model, params, prompts, args.new_tokens, max_len)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"[serve_lm] decoded {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. prefill+compile)")
    for b in range(min(args.batch, 2)):
        print(f"  req{b}: {np.asarray(out[b])[:12].tolist()} ...")
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert out.shape == (args.batch, args.new_tokens)
    print("done.")


if __name__ == "__main__":
    main()
