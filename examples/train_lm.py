"""End-to-end driver: train a ~100M-class LM for a few hundred steps.

Uses a width/depth-reduced smollm-family config (llama arch: GQA + RoPE +
SwiGLU) against the deterministic synthetic pipeline, with the full
production loop: AdamW + cosine schedule, bf16 activations / f32 master
weights, grad accumulation, async atomic checkpoints, restart support.

Defaults are sized so a few hundred steps finish on this container's CPU
(~25M params, seq 256). --full trains the real 360M config (TPU-sized).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models import build_model
from repro.train import (OptConfig, Trainer, TrainerConfig, TrainConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="train the real smollm-360m config")
    args = ap.parse_args()

    cfg = get_config("smollm-360m")
    if not args.full:
        # ~25M-param reduction of the same family (CPU-friendly)
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
            head_dim=64, d_ff=1024, vocab=8192, activation_dtype="float32")
    model = build_model(cfg)
    print(f"[train_lm] {cfg.name}: {model.n_params()/1e6:.1f}M params")

    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         seed=0)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 4, 50),
        ckpt_dir=args.ckpt_dir, log_every=10,
        train=TrainConfig(opt=OptConfig(lr=6e-4, warmup_steps=30,
                                        total_steps=args.steps),
                          microbatches=2))
    trainer = Trainer(model, pipe, tcfg)
    _, _, log = trainer.run(resume=args.resume)
    first = sum(m["loss"] for m in log[:10]) / max(len(log[:10]), 1)
    last = sum(m["loss"] for m in log[-10:]) / max(len(log[-10:]), 1)
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} over {len(log)} steps")


if __name__ == "__main__":
    main()
