"""Docs build check: doctests + link + DESIGN.md §-reference validation.

The docs "build" for this repo is three executable guarantees, run by
the CI ``docs`` job and by tier-1 via tests/test_docs.py:

1. **doctest** — every ``>>>`` example in ``docs/*.md`` runs
   (``python -m doctest`` semantics via doctest.testfile), so the
   quickstart commands and API snippets can't rot;
2. **links** — every relative markdown link in ``docs/*.md`` and
   ``DESIGN.md`` points at an existing file;
3. **§-references** — every ``DESIGN.md §N`` citation anywhere in the
   repo (docstrings cite DESIGN sections as load-bearing anchors) names
   a section header that actually exists, so DESIGN.md cross-refs can't
   dangle again (the PR-1 cleanup, now enforced).

Run: ``PYTHONPATH=src python docs/check_docs.py`` from the repo root.
Exits nonzero with a list of failures.
"""

from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

# files whose prose/docstrings may cite DESIGN.md sections
_REF_GLOBS = ("src/**/*.py", "tests/*.py", "benchmarks/*.py",
              "examples/*.py", "docs/*.md", "*.md")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_DESIGN_REF_RE = re.compile(
    r"DESIGN\.md[^\S\n]*(§\w[\w-]*(?:[–-]+§\w[\w-]*)*)")
_SECTION_TOKEN_RE = re.compile(r"§(\w[\w-]*)")


def doc_files() -> list[Path]:
    return sorted(DOCS.glob("*.md"))


def design_sections() -> set[str]:
    """Tokens of every ``## §N``-style header in DESIGN.md."""
    out = set()
    for line in (REPO / "DESIGN.md").read_text().splitlines():
        m = re.match(r"^#+\s*§([\w-]+)", line.strip())
        if m:
            out.add(m.group(1))
    return out


def check_doctests() -> list[str]:
    """Run every docs/*.md through doctest (fresh globals per file)."""
    sys.path.insert(0, str(REPO / "src"))
    failures = []
    for md in doc_files():
        res = doctest.testfile(str(md), module_relative=False, verbose=False,
                               optionflags=doctest.NORMALIZE_WHITESPACE)
        if res.failed:
            failures.append(f"{md.relative_to(REPO)}: {res.failed} of "
                            f"{res.attempted} doctest example(s) failed")
    return failures


def check_links() -> list[str]:
    """Relative markdown links in docs/ + DESIGN.md must resolve."""
    failures = []
    for md in doc_files() + [REPO / "DESIGN.md"]:
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                failures.append(
                    f"{md.relative_to(REPO)}: dangling link -> {target}")
    return failures


def check_design_refs() -> list[str]:
    """Every ``DESIGN.md §N`` citation must name a real section."""
    sections = design_sections()
    failures = []
    for pattern in _REF_GLOBS:
        for f in REPO.glob(pattern):
            if not f.is_file():
                continue
            text = f.read_text(errors="replace")
            for ref in _DESIGN_REF_RE.findall(text):
                for token in _SECTION_TOKEN_RE.findall(ref):
                    if token not in sections:
                        failures.append(
                            f"{f.relative_to(REPO)}: dangling reference "
                            f"DESIGN.md §{token}")
    return failures


def main() -> int:
    failures = check_links() + check_design_refs() + check_doctests()
    if failures:
        print(f"{len(failures)} docs failure(s):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    n = len(doc_files())
    print(f"docs OK: {n} files doctested, links + DESIGN.md §-refs resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
