"""Resident-block layer: neighbour tables, block round-trips, fused pipeline."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HILBERT, MORTON, ROW_MAJOR, OrderingSpec,
                        blockize, blockize_with_halo, unblockize)
from repro.core.neighbors import (FACE_COLS, OFFSETS_FACE, OFFSETS_FULL,
                                  SELF_COL, block_kind_of, neighbor_table,
                                  neighbor_table_device, ring_perms)
from repro.core.layout import block_order
from repro.core.orderings import path_to_rmo, rmo_to_path
from repro.kernels import ref
from repro.kernels.ops import uniform_weights
from repro.kernels.stencil3d import stencil_sum_blocks, stencil_sum_resident
from repro.stencil import Gol3d, Gol3dConfig, ResidentPipeline
from repro.stencil.pipeline import repack_bytes_per_step, resident_bytes_per_step

rng = np.random.default_rng(7)

KINDS = ("row_major", "column_major", "morton", "hilbert")
HYBRID = OrderingSpec("hybrid", tile=4, outer="hilbert", inner="row_major")


# ------------------------------------------------------------ block round-trip
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("M,T", [(16, 8), (16, 4), (32, 8), (8, 8)])
def test_blockize_roundtrip(kind, M, T):
    cube = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    blocks = blockize(cube, T, kind=kind)
    assert blocks.shape == ((M // T) ** 3, T, T, T)
    back = unblockize(blocks, M, kind=kind)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(cube))


def test_permutations_are_int32():
    """DESIGN.md §2: permutation tables ride int32 (gather/prefetch width)."""
    for spec in (ROW_MAJOR, MORTON, HILBERT, HYBRID):
        assert rmo_to_path(spec, 16).dtype == np.int32
        assert path_to_rmo(spec, 16).dtype == np.int32


# ------------------------------------------------------------ neighbour tables
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("nt", [2, 4, 8])
@pytest.mark.parametrize("periodic", [True, False])
def test_neighbor_table_brute_force(kind, nt, periodic):
    """Every table entry matches direct coordinate arithmetic."""
    tab = neighbor_table(kind, nt, periodic=periodic)
    assert tab.shape == (nt ** 3, 27)
    assert tab.dtype == np.int32
    bo = block_order(kind, nt)  # path pos -> (k,i,j)
    lin_to_path = {(int(k), int(i), int(j)): t
                   for t, (k, i, j) in enumerate(bo)}
    for t in range(nt ** 3):
        k, i, j = (int(c) for c in bo[t])
        for o, (dk, di, dj) in enumerate(OFFSETS_FULL):
            if periodic:
                key = ((k + dk) % nt, (i + di) % nt, (j + dj) % nt)
            else:
                key = (min(max(k + dk, 0), nt - 1),
                       min(max(i + di, 0), nt - 1),
                       min(max(j + dj, 0), nt - 1))
            assert tab[t, o] == lin_to_path[key], (t, o, key)


def test_neighbor_table_face_variant():
    tab6 = neighbor_table("hilbert", 4, connectivity="face")
    tab27 = neighbor_table("hilbert", 4)
    assert tab6.shape == (64, 6)
    np.testing.assert_array_equal(tab6, tab27[:, list(FACE_COLS)])
    # column order is [k-, k+, i-, i+, j-, j+]
    assert tuple(OFFSETS_FULL[c] for c in FACE_COLS) == OFFSETS_FACE
    # self column is the identity
    np.testing.assert_array_equal(tab27[:, SELF_COL], np.arange(64))


def test_neighbor_table_spec_generic():
    """OrderingSpec and its block-kind string resolve to the same table."""
    assert block_kind_of(HILBERT) == "hilbert"
    assert block_kind_of(HYBRID) == "hilbert"
    assert block_kind_of("morton") == "morton"
    np.testing.assert_array_equal(neighbor_table(HILBERT, 4),
                                  neighbor_table("hilbert", 4))
    np.testing.assert_array_equal(neighbor_table(HYBRID, 4),
                                  neighbor_table("hilbert", 4))


def test_neighbor_table_cached_and_readonly():
    a = neighbor_table("morton", 4)
    assert neighbor_table("morton", 4) is a
    assert not a.flags.writeable
    d = neighbor_table_device("morton", 4)
    assert neighbor_table_device("morton", 4) is d


def test_ring_perms():
    fwd, bwd = ring_perms(4)
    assert fwd == [(0, 1), (1, 2), (2, 3), (3, 0)]
    assert bwd == [(0, 3), (1, 0), (2, 1), (3, 2)]


# ------------------------------------------------- in-kernel halo vs repacked
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("g", [1, 2])
def test_assemble_halo_bit_identical(kind, g):
    M, T = 16, 8
    cube = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    halo = blockize_with_halo(cube, T, g, kind=kind, periodic=True)
    store = blockize(cube, T, kind=kind)
    nbr = neighbor_table_device(kind, M // T)
    asm = ref.assemble_halo_ref(store, nbr, g)
    np.testing.assert_array_equal(np.asarray(asm), np.asarray(halo))


@pytest.mark.parametrize("kind", ("morton", "hilbert"))
@pytest.mark.parametrize("g,T", [(1, 8), (2, 8), (1, 4), (4, 4)])
def test_resident_kernel_bit_identical(kind, g, T):
    """Pallas resident kernel == Pallas repack kernel, bit for bit."""
    M = 16
    cube = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(2 * g + 1,) * 3).astype(np.float32))
    old = stencil_sum_blocks(
        blockize_with_halo(cube, T, g, kind=kind, periodic=True), w, g=g)
    new = stencil_sum_resident(blockize(cube, T, kind=kind), w,
                               neighbor_table_device(kind, M // T), g=g)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


def test_resident_kernel_rejects_non_dividing_g():
    store = jnp.zeros((8, 8, 8, 8), jnp.float32)
    nbr = neighbor_table_device("morton", 2)
    with pytest.raises(ValueError):
        stencil_sum_resident(store, jnp.zeros((7, 7, 7)), nbr, g=3)


# -------------------------------------------------------------- fused pipeline
@pytest.mark.parametrize("ordering", [ROW_MAJOR, MORTON, HILBERT, HYBRID],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("M", [16, 32])
@pytest.mark.parametrize("g", [1, 2])
def test_resident_pipeline_matches_repack(ordering, M, g):
    """Acceptance: resident run bit-identical to the per-step repack run."""
    steps = 3
    a = Gol3d(Gol3dConfig(M=M, g=g, ordering=ordering, block_T=8))
    b = Gol3d(Gol3dConfig(M=M, g=g, ordering=ordering, block_T=8))
    sa = a.run(steps)
    sb = b.run_resident(steps)
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))


@pytest.mark.parametrize("g", [1, 2])
def test_resident_pipeline_matches_oracle(g):
    """K=4 fused steps == the ordering-independent canonical oracle."""
    app = Gol3d(Gol3dConfig(M=16, g=g, ordering=HILBERT, block_T=8))
    want = app.reference_run(4)
    app.run_resident(4)
    np.testing.assert_array_equal(np.asarray(app.cube), np.asarray(want))


def test_resident_pipeline_kernel_mode():
    app = Gol3d(Gol3dConfig(M=16, g=1, ordering=MORTON, block_T=8,
                            use_kernel=True))
    want = app.reference_run(2)
    app.run_resident(2)
    np.testing.assert_array_equal(np.asarray(app.cube), np.asarray(want))


def test_resident_step_preserves_weights_semantics():
    """One resident step == one repack gol3d step at the op level."""
    M, T, g = 16, 8, 1
    pipe = ResidentPipeline(M=M, T=T, g=g, kind="morton")
    cube = jnp.asarray((rng.random((M, M, M)) < 0.3).astype(np.float32))
    got = pipe.run(cube, 1)
    want = ref.gol3d_step_ref(cube, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bytes_model_resident_wins():
    """The point of the refactor: strictly fewer bytes/step for K >= 2,
    with no ((T+2g)/T)³ duplication and no per-step O(M³) repack."""
    for M, T, g in [(32, 8, 1), (32, 8, 2), (64, 8, 1), (64, 16, 2)]:
        rep = repack_bytes_per_step(M, T, g)
        for K in (2, 10, 100):
            res = resident_bytes_per_step(M, T, g, K)
            assert res < rep, (M, T, g, K)
        # resident store itself is exactly M³ items — no halo duplication
        pipe = ResidentPipeline(M=M, T=T, g=g)
        assert pipe.nb * T ** 3 == M ** 3
