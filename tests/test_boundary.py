"""Physical boundary conditions end-to-end (DESIGN.md §8).

Coverage layers, mirroring the periodic suites:

- contract + table units: BoundarySpec parsing, pad_cube vs np.pad,
  boundary_face_table flag counts (faces/edges/corners), the shared
  in-window ghost refresh (kernels/rules.apply_window_bc) against the
  padded-cube corner semantics;
- resident matrix: clamped ResidentPipeline — kernel and oracle, fused
  S-deep vs sequential bit-identity, gol exact against the clamped
  global oracle — including the M == T single-block grid where every
  face of the only block is clamped;
- exchange: open-ring ppermute partner lists, the clamped bytes model
  (edge shards strictly fewer bytes; extents == packed slab shapes),
  exchange_shell on a 1×1×1 mesh against pad_cube (no ppermute pairs at
  all on a clamped single-shard mesh — asserted on the jaxpr);
- the ≥8-device clamped acceptance matrix: DistributedPipeline S-deep
  vs S sequential clamped make_distributed_step, all four orderings ×
  {gol, jacobi}, plus the no-wrap-traffic jaxpr assert — in-process on
  the multi-device CI job, subprocess under tier-1.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (COLUMN_MAJOR, HILBERT, MORTON, NEUMANN0, PERIODIC,
                        ROW_MAJOR, BoundarySpec, MixedBoundary, apply_ordering,
                        as_boundary, axes_periodic, blockize,
                        boundary_face_table, dirichlet, mixed, pad_cube,
                        unblockize)
from repro.core.neighbors import neighbor_table_device, ring_perms
from repro.kernels import ref as kref
from repro.kernels.ops import uniform_weights
from repro.kernels.rules import apply_window_bc
from repro.kernels.stencil3d import stencil_step_fused
from repro.stencil import (DistributedPipeline, Gol3d, Gol3dConfig,
                           ResidentPipeline, distributed_bytes_per_step,
                           exchange_bytes_per_step, exchange_face_items,
                           exchange_items_per_exchange, make_stencil_mesh,
                           resident_bytes_per_step)
from repro.stencil.halo import exchange_shell, shard_substeps

rng = np.random.default_rng(23)

ORDERINGS = (ROW_MAJOR, COLUMN_MAJOR, MORTON, HILBERT)
CLAMPED = (NEUMANN0, dirichlet(0.0))


def _cube(M, rule="gol"):
    if rule == "gol":
        return (rng.random((M, M, M)) < 0.3).astype(np.float32)
    return rng.normal(size=(M, M, M)).astype(np.float32)


def _oracle_run(cube, g, bc, steps):
    want = jnp.asarray(cube)
    for _ in range(steps):
        want = kref.gol3d_step_ref(want, g, bc=bc)
    return np.asarray(want)


# ------------------------------------------------------------- contract units
def test_boundary_spec_contract():
    assert as_boundary("periodic") == PERIODIC and not PERIODIC.clamped
    assert as_boundary("neumann0") == NEUMANN0 and NEUMANN0.clamped
    assert as_boundary(NEUMANN0) is NEUMANN0
    d = dirichlet(1.5)
    assert d.clamped and d.value == 1.5
    assert hash(d) == hash(BoundarySpec("dirichlet", 1.5))  # jit-static key
    with pytest.raises(ValueError):
        BoundarySpec("reflect")


def test_pad_cube_matches_numpy_pad():
    c = _cube(4, "jacobi")
    np.testing.assert_array_equal(np.asarray(pad_cube(jnp.asarray(c), 2, PERIODIC)),
                                  np.pad(c, 2, mode="wrap"))
    np.testing.assert_array_equal(np.asarray(pad_cube(jnp.asarray(c), 2, NEUMANN0)),
                                  np.pad(c, 2, mode="edge"))
    np.testing.assert_array_equal(
        np.asarray(pad_cube(jnp.asarray(c), 1, dirichlet(3.0))),
        np.pad(c, 1, constant_values=3.0))


def test_boundary_face_table_flag_counts():
    """Blocks adjacent to 0/1/2/3 clamped faces: interior, face, edge,
    corner — the multi-clamped-face population the refresh must handle."""
    nt = 4
    tab = boundary_face_table("hilbert", nt)
    assert tab.shape == (nt ** 3, 6)
    nflags = tab.sum(axis=1)
    assert (nflags == 0).sum() == (nt - 2) ** 3          # interior
    assert (nflags == 1).sum() == 6 * (nt - 2) ** 2      # face blocks
    assert (nflags == 2).sum() == 12 * (nt - 2)          # edge blocks
    assert (nflags == 3).sum() == 8                      # corner blocks
    # single-block grid: the one block owns all six domain faces
    np.testing.assert_array_equal(boundary_face_table("morton", 1),
                                  np.ones((1, 6), np.int32))
    # opposite columns never both set for nt >= 2
    assert not ((tab[:, 0] & tab[:, 1]).any())


@pytest.mark.parametrize("bc", CLAMPED, ids=lambda b: b.kind)
def test_apply_window_bc_matches_pad(bc):
    """Refreshing a fully-flagged scrambled window reproduces pad_cube —
    including the per-axis-sequential corner composition."""
    T, h = 4, 2
    core = _cube(T, "jacobi")
    want = np.asarray(pad_cube(jnp.asarray(core), h, bc))
    scr = want.copy()
    scr[:h], scr[-h:] = 9.0, 9.0                    # poison every ghost site
    scr[:, :h], scr[:, -h:] = 9.0, 9.0
    scr[:, :, :h], scr[:, :, -h:] = 9.0, 9.0
    flags = np.ones((1, 6), np.int32)
    got = apply_window_bc(jnp.asarray(scr)[None], flags, h, bc)
    np.testing.assert_array_equal(np.asarray(got)[0], want)
    # partially flagged: only the k-lo ghost refreshes (over the spans
    # the other faces would deliver by exchange); everything else —
    # including the k-hi ghost — keeps its existing content
    flags = np.array([[1, 0, 0, 0, 0, 0]], np.int32)
    got = np.asarray(apply_window_bc(jnp.asarray(scr)[None], flags, h, bc))[0]
    np.testing.assert_array_equal(got[:h, h:-h, h:-h], want[:h, h:-h, h:-h])
    np.testing.assert_array_equal(got[-h:], scr[-h:])    # k-hi untouched
    np.testing.assert_array_equal(got[h:-h], scr[h:-h])  # interior untouched


# ----------------------------------------------------------- resident matrix
@pytest.mark.parametrize("kind", ["morton", "hilbert"])
@pytest.mark.parametrize("rule", ["gol", "jacobi"])
@pytest.mark.parametrize("bc", CLAMPED, ids=lambda b: b.kind)
def test_resident_clamped_fused_matches_sequential(kind, rule, bc):
    """Clamped fused S=4 (kernel) == 4 sequential S=1 steps (kernel and
    oracle families), and gol == the clamped padded-cube global oracle."""
    M, T, g, S = 16, 8, 1, 4
    cube = _cube(M, rule)
    deep = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=S, rule=rule, bc=bc,
                            use_kernel=True)
    seq = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=1, rule=rule, bc=bc,
                           use_kernel=True)
    a = np.asarray(deep.run(jnp.asarray(cube), S))
    np.testing.assert_array_equal(a, np.asarray(seq.run(jnp.asarray(cube), S)))
    ora = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=S, rule=rule, bc=bc)
    b = np.asarray(ora.run(jnp.asarray(cube), S))
    if rule == "gol":  # integer-valued sums: exact across families
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(b, _oracle_run(cube, g, bc, S))
    else:
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S", [1, 2, 4, 8])
def test_single_block_grid_clamped(S):
    """M == T: the store is one block with all six faces clamped — the
    deepest temporal blocking the kernel admits still matches the
    oracle (acceptance: M==T single-block grids)."""
    M = T = 8
    g = 1
    cube = _cube(M)
    for bc in CLAMPED:
        pipe = ResidentPipeline(M=M, T=T, g=g, kind="morton", S=S, bc=bc,
                                use_kernel=True)
        got = np.asarray(pipe.run(jnp.asarray(cube), S))
        np.testing.assert_array_equal(got, _oracle_run(cube, g, bc, S),
                                      err_msg=f"{bc.kind} S={S}")


def test_multi_clamped_face_blocks_against_oracle():
    """nt=4 grid (face/edge/corner/interior block mix) under neumann0:
    blocks adjacent to ≥2 clamped faces refresh both axes correctly."""
    M, T, g, S = 32, 8, 1, 2
    cube = _cube(M)
    pipe = ResidentPipeline(M=M, T=T, g=g, kind="hilbert", S=S, bc=NEUMANN0)
    got = np.asarray(pipe.run(jnp.asarray(cube), 2 * S))
    np.testing.assert_array_equal(got, _oracle_run(cube, g, NEUMANN0, 2 * S))


def test_fused_kernel_requires_flags_when_clamped():
    store = jnp.zeros((8, 8, 8, 8), jnp.float32)
    nbr = neighbor_table_device("morton", 2, periodic=False)
    with pytest.raises(ValueError):
        stencil_step_fused(store, uniform_weights(1), nbr, None,
                           g=1, S=1, rule="gol", bc=NEUMANN0)


def test_gol3d_config_threads_bc():
    """The app-level knob: repack, resident and reference runs agree
    under a clamped config (string form accepted)."""
    app = Gol3d(Gol3dConfig(M=16, g=1, ordering=MORTON, block_T=8,
                            substeps=2, bc="neumann0"))
    assert app.cfg.bc == NEUMANN0
    want = np.asarray(app.reference_run(2))
    s_rep = np.asarray(Gol3d(app.cfg).run(2))
    app.run_resident(2)
    np.testing.assert_array_equal(np.asarray(app.cube), want)
    np.testing.assert_array_equal(np.asarray(app.state_path), s_rep)


# ------------------------------------------------- exchange: rings and model
def test_ring_perms_open_rings_have_no_wrap_pairs():
    fwd, bwd = ring_perms(4, periodic=False)
    assert fwd == [(0, 1), (1, 2), (2, 3)] and bwd == [(1, 0), (2, 1), (3, 2)]
    assert ring_perms(1, periodic=False) == ([], [])
    # periodic keeps the wrap links (and the legacy pair order)
    fwd_p, bwd_p = ring_perms(4)
    assert (3, 0) in fwd_p and (0, 3) in bwd_p


def test_clamped_exchange_model():
    """Acceptance: clamped exchange bytes match packed extents exactly,
    and edge shards exchange strictly fewer bytes than periodic."""
    from repro.core.surfaces import shell_slab_shapes

    M, g, S = 16, 1, 4
    h = S * g
    sizes = exchange_face_items(M, g, S)
    shp = shell_slab_shapes(M, h)
    # the model's per-face extents ARE the packed slab shapes
    assert sizes == tuple(int(np.prod(s)) for s in (shp[0], shp[2], shp[4]))
    per = exchange_items_per_exchange(M, g, S)
    assert per == 2 * sum(sizes)
    procs = (2, 2, 2)
    corner = exchange_items_per_exchange(M, g, S, bc=NEUMANN0, procs=procs,
                                         coords=(0, 0, 0))
    assert corner == sum(sizes)          # one neighbour per axis
    assert corner < per                  # strictly fewer than periodic
    # interior shard of a 4³ mesh: both neighbours exist -> periodic volume
    interior = exchange_items_per_exchange(M, g, S, bc=NEUMANN0,
                                           procs=(4, 4, 4), coords=(1, 2, 1))
    assert interior == per
    # mesh mean: 2(p-1)/p faces per axis, equals the coords average
    mean = exchange_items_per_exchange(M, g, S, bc=NEUMANN0, procs=procs)
    allc = [exchange_items_per_exchange(M, g, S, bc=NEUMANN0, procs=procs,
                                        coords=(a, b, c))
            for a in range(2) for b in range(2) for c in range(2)]
    assert mean == pytest.approx(sum(allc) / len(allc))
    assert mean < per
    # bytes-per-step and the distributed total decompose consistently
    assert exchange_bytes_per_step(M, g, S, bc=NEUMANN0, procs=procs) \
        == pytest.approx(4 * mean / S)
    assert distributed_bytes_per_step(M, 8, g, 10, S=S, bc=NEUMANN0,
                                      procs=procs) == pytest.approx(
        resident_bytes_per_step(M, 8, g, 10, S=S) + 4 * mean / S)
    with pytest.raises(ValueError):
        exchange_items_per_exchange(M, g, S, bc=NEUMANN0)  # needs procs


def test_clamped_plan_minimises_joint_cost():
    """plan(bc=clamped) optimises against the smaller exchange surface
    and never exceeds an enumerable candidate."""
    mesh = make_stencil_mesh((1, 1, 1))
    pipe = DistributedPipeline.plan(mesh, HILBERT, 16, g=1, bc=NEUMANN0,
                                    vmem_limit=256 * 1024)
    assert pipe.bc == NEUMANN0
    best = pipe.bytes_per_step(10)
    T = 1
    while T <= 16:
        if 16 % T == 0:
            S = 1
            while S <= 8:
                if S <= T and T % S == 0:
                    from repro.stencil import fused_vmem_bytes
                    if fused_vmem_bytes(T, 1, S) <= 256 * 1024:
                        assert best <= distributed_bytes_per_step(
                            16, T, 1, 10, S=S, bc=NEUMANN0, procs=pipe.procs)
                S *= 2
        T *= 2
    # per-shard view: the corner shard of a real mesh models fewer ICI
    # bytes than the periodic torus, the mean sits between
    p222 = DistributedPipeline(mesh=mesh, spec=HILBERT, M=16, T=8, g=1, S=2,
                               bc=NEUMANN0)
    per = exchange_bytes_per_step(16, 1, 2)
    assert p222.exchange_bytes_per_step(coords=(0, 0, 0)) < per


def test_clamped_benchmark_rows_share_accounting():
    """Satellite: the clamped benchmark rows carry exactly the pipeline
    model's numbers — same single-accounting discipline as the periodic
    rows (tests/test_fused_stencil.py)."""
    sys.path.insert(0, ".")
    from benchmarks.run import _parse_derived
    from benchmarks.stencil_update import CLAMPED_PROCS, clamped_derived

    M_, T_, g, S, K = 32, 8, 1, 4, 10
    d = _parse_derived(clamped_derived(M_, T_, g, S, K))
    assert d["bc"] == "neumann0"
    assert d["fused_bytes_per_substep"] == round(
        resident_bytes_per_step(M_, T_, g, K, S=S))  # HBM: bc-independent
    assert d["ici_bytes_per_step_periodic"] == round(
        exchange_bytes_per_step(M_, g, S))
    assert d["ici_bytes_per_step_clamped"] == round(exchange_bytes_per_step(
        M_, g, S, bc=NEUMANN0, procs=CLAMPED_PROCS))
    assert d["ici_bytes_per_step_edge_shard"] == round(exchange_bytes_per_step(
        M_, g, S, bc=NEUMANN0, procs=CLAMPED_PROCS, coords=(0, 0, 0)))
    # the acceptance ordering, as reported: edge shard < mesh mean < torus
    assert d["ici_bytes_per_step_edge_shard"] \
        <= d["ici_bytes_per_step_clamped"] < d["ici_bytes_per_step_periodic"]
    assert d["distributed_bytes_per_step"] == round(distributed_bytes_per_step(
        M_, T_, g, K, S=S, bc=NEUMANN0, procs=CLAMPED_PROCS))


# ----------------------------------------- exchange semantics (1×1×1 mesh)
def _collect_ppermute_perms(jaxpr):
    """All ppermute partner lists anywhere in a (closed) jaxpr."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "ppermute":
            out.append(tuple(eqn.params["perm"]))
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    out += _collect_ppermute_perms(sub.jaxpr)
                elif isinstance(sub, jax.core.Jaxpr):
                    out += _collect_ppermute_perms(sub)
    return out


@pytest.mark.parametrize("bc", CLAMPED, ids=lambda b: b.kind)
def test_exchange_shell_clamped_single_shard_matches_pad(bc):
    """On a 1×1×1 clamped mesh every ring is empty — zero ppermute pairs
    in the jaxpr — and the six slabs must equal the pad_cube ghost."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    M, T, h = 16, 8, 2
    mesh = make_stencil_mesh((1, 1, 1))
    cube = _cube(M, "jacobi")
    store = blockize(jnp.asarray(cube), T, kind="hilbert")
    fn = shard_map(
        lambda st: exchange_shell(st.reshape(-1), "hilbert", M, T, h, bc=bc),
        mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    perms = [p for p in _collect_ppermute_perms(jax.make_jaxpr(fn)(store).jaxpr)
             if p]
    assert perms == []  # clamped single-shard mesh: no pairs anywhere
    k_lo, k_hi, i_lo, i_hi, j_lo, j_hi = map(np.asarray, fn(store))
    xp = np.asarray(pad_cube(jnp.asarray(cube), h, bc))
    e = M + 2 * h
    np.testing.assert_array_equal(k_lo, xp[:h, h:h + M, h:h + M])
    np.testing.assert_array_equal(k_hi, xp[e - h:, h:h + M, h:h + M])
    np.testing.assert_array_equal(i_lo, xp[:, :h, h:h + M])
    np.testing.assert_array_equal(i_hi, xp[:, e - h:, h:h + M])
    np.testing.assert_array_equal(j_lo, xp[:, :, :h])
    np.testing.assert_array_equal(j_hi, xp[:, :, e - h:])


@pytest.mark.parametrize("use_kernel", [False, True])
def test_shard_substeps_clamped_single_shard_matches_oracle(use_kernel):
    """One clamped deep round on a 1×1×1 mesh == S clamped oracle steps."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    M, T, g, S = 16, 8, 1, 4
    mesh = make_stencil_mesh((1, 1, 1))
    for bc in CLAMPED:
        cube = _cube(M)
        store = blockize(jnp.asarray(cube), T, kind="morton")
        fn = shard_map(
            lambda st: shard_substeps(st, kind="morton", M=M, g=g, S=S,
                                      bc=bc, use_kernel=use_kernel),
            mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
        got = np.asarray(unblockize(fn(store), M, kind="morton"))
        np.testing.assert_array_equal(got, _oracle_run(cube, g, bc, S),
                                      err_msg=bc.kind)


# --------------------------------------------- per-face mixed contracts (§8)
def test_mixed_boundary_contract():
    """mixed() coerces strings per axis, collapses uniform triples, and
    exposes the shared per-axis view every consumer reads."""
    duct = mixed(k="neumann0")
    assert isinstance(duct, MixedBoundary) and duct.kind == "mixed"
    assert duct.clamped and [a.kind for a in duct.axes] == \
        ["neumann0", "periodic", "periodic"]
    assert axes_periodic(duct) == (False, True, True)
    assert mixed(k=NEUMANN0, i=NEUMANN0, j=NEUMANN0) == NEUMANN0  # collapse
    assert mixed() == PERIODIC
    assert as_boundary(duct) is duct
    assert axes_periodic(PERIODIC) == (True, True, True)
    assert axes_periodic(NEUMANN0) == (False, False, False)
    assert PERIODIC.axes == (PERIODIC,) * 3  # uniform specs self-expose
    assert hash(duct) == hash(mixed(k="neumann0"))  # jit-static key
    with pytest.raises(ValueError):
        MixedBoundary("neumann0", PERIODIC, PERIODIC)  # specs, not strings


def test_mixed_pad_cube_per_axis():
    """pad_cube under a mixed contract pads each axis under its own spec
    in k,i,j order — wrap on periodic axes includes clamped ghosts."""
    c = _cube(4, "jacobi")
    duct = mixed(k=dirichlet(2.0))
    got = np.asarray(pad_cube(jnp.asarray(c), 1, duct))
    want = np.pad(c, [(1, 1), (0, 0), (0, 0)], constant_values=2.0)
    want = np.pad(want, [(0, 0), (1, 1), (1, 1)], mode="wrap")
    np.testing.assert_array_equal(got, want)


def test_mixed_neighbor_table_per_axis():
    """The block table wraps on periodic axes and clamps on clamped ones
    — per axis, from one periodic=(…) knob."""
    from repro.core.neighbors import neighbor_table

    nt = 4
    per = neighbor_table("row_major", nt, periodic=True)
    cla = neighbor_table("row_major", nt, periodic=False)
    mix = neighbor_table("row_major", nt, periodic=(False, True, True))
    # row_major path position == linear block id, so rows index directly
    np.testing.assert_array_equal(mix[:, 13], per[:, 13])
    # a k-edge, i/j-interior block: k-offsets clamp, i/j offsets wrap
    k_lo_col = 4       # offset (-1, 0, 0): column 0*9 + 1*3 + 1
    blk = 0 * nt * nt + 2 * nt + 2   # (k=0, i=2, j=2)
    assert mix[blk, k_lo_col] == cla[blk, k_lo_col] != per[blk, k_lo_col]
    j_lo_col = 12      # offset (0, 0, -1): column 1*9 + 1*3 + 0
    blk_j = 2 * nt * nt + 2 * nt + 0  # (k=2, i=2, j=0): j wraps under mix
    assert mix[blk_j, j_lo_col] == per[blk_j, j_lo_col] \
        != cla[blk_j, j_lo_col]
    assert not np.array_equal(mix, per)


@pytest.mark.parametrize("kind", ["morton", "hilbert"])
def test_resident_mixed_matches_oracle(kind):
    """Acceptance: clamped k + periodic i/j through the fused resident
    pipeline (kernel and oracle) == the per-axis padded-cube oracle,
    bit-identical, S-deep."""
    M, T, g, S = 16, 8, 1, 4
    duct = mixed(k=NEUMANN0)
    cube = _cube(M)
    deep = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=S, bc=duct,
                            use_kernel=True)
    seq = ResidentPipeline(M=M, T=T, g=g, kind=kind, S=1, bc=duct)
    a = np.asarray(deep.run(jnp.asarray(cube), S))
    np.testing.assert_array_equal(a, np.asarray(seq.run(jnp.asarray(cube), S)))
    np.testing.assert_array_equal(a, _oracle_run(cube, g, duct, S))


def test_mixed_exchange_model_per_axis():
    """Only the clamped axis shrinks: periodic axes keep the full 2-face
    volume, the clamped axis counts existing neighbours."""
    M, g, S = 16, 1, 4
    sizes = exchange_face_items(M, g, S)
    duct = mixed(k=NEUMANN0)
    per = exchange_items_per_exchange(M, g, S)
    corner = exchange_items_per_exchange(M, g, S, bc=duct, procs=(2, 2, 2),
                                         coords=(0, 0, 0))
    # k contributes 1 face (one neighbour), i/j the full 2 faces each
    assert corner == sizes[0] + 2 * sizes[1] + 2 * sizes[2]
    assert corner < per
    mean = exchange_items_per_exchange(M, g, S, bc=duct, procs=(2, 2, 2))
    assert mean == sizes[0] * 2 * (2 - 1) / 2 + 2 * sizes[1] + 2 * sizes[2]
    # a fully periodic mixed spec never needs procs
    assert exchange_items_per_exchange(M, g, S, bc=mixed()) == per
    with pytest.raises(ValueError):
        exchange_items_per_exchange(M, g, S, bc=duct)  # clamped k needs procs


@pytest.mark.parametrize("use_kernel", [False, True])
def test_shard_substeps_mixed_single_shard_matches_oracle(use_kernel):
    """One mixed deep round on a 1×1×1 mesh == S mixed oracle steps, and
    the jaxpr carries ppermute pairs for the periodic axes only."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    M, T, g, S = 16, 8, 1, 4
    duct = mixed(k=NEUMANN0)
    mesh = make_stencil_mesh((1, 1, 1))
    cube = _cube(M)
    store = blockize(jnp.asarray(cube), T, kind="hilbert")
    fn = shard_map(
        lambda st: shard_substeps(st, kind="hilbert", M=M, g=g, S=S,
                                  bc=duct, use_kernel=use_kernel),
        mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    got = np.asarray(unblockize(fn(store), M, kind="hilbert"))
    np.testing.assert_array_equal(got, _oracle_run(cube, g, duct, S))
    # structural: the clamped k ring is empty, the periodic i/j rings
    # keep their (self-send) pairs — ppermute pairs on periodic axes only
    perms = [p for p in
             _collect_ppermute_perms(jax.make_jaxpr(fn)(store).jaxpr) if p]
    assert len(perms) == 4  # 2 ppermutes × 2 periodic axes; k's are empty


# --------------------------------------- clamped acceptance matrix (≥ 8 dev)
def _run_clamped_matrix():
    """Acceptance: clamped DistributedPipeline S-deep run == S sequential
    clamped make_distributed_step steps, bit-identical, for all four
    orderings × {gol, jacobi}; gol also equals the clamped global
    oracle. Structural: the clamped step's jaxpr has open rings only —
    every ppermute pair is a ±1 hop, no wrap pair, and each axis carries
    one pair fewer than the periodic step.
    """
    from repro.stencil import make_distributed_step, shard_state, unshard_state

    mesh = make_stencil_mesh((2, 2, 2))
    local_M, g, GM = 8, 1, 16
    r = np.random.default_rng(5)
    data = {
        "gol": (r.random((GM, GM, GM)) < 0.35).astype(np.float32),
        "jacobi": r.normal(size=(GM, GM, GM)).astype(np.float32),
    }
    cases = [(NEUMANN0, (1, 2, 4)), (dirichlet(0.0), (2,))]
    for spec in ORDERINGS:
        for rule, gcube in data.items():
            for bc, depths in cases:
                st0 = shard_state(jnp.asarray(gcube), spec, (2, 2, 2))
                step = make_distributed_step(mesh, spec, local_M, g,
                                             rule=rule, bc=bc)
                for S in depths:
                    pipe = DistributedPipeline(mesh=mesh, spec=spec,
                                               M=local_M, T=8, g=g, S=S,
                                               rule=rule, bc=bc)
                    got = np.asarray(jax.block_until_ready(pipe.run(st0, S)))
                    want = st0
                    for _ in range(S):
                        want = step(want)
                    want = np.asarray(jax.block_until_ready(want))
                    assert np.array_equal(got, want), \
                        (spec.name, rule, bc.kind, S)
                if rule == "gol":
                    # the per-step reference itself against the clamped
                    # global padded-cube oracle (two steps)
                    ora = jnp.asarray(gcube)
                    w2 = st0
                    for _ in range(2):
                        ora = kref.gol3d_step_ref(ora, g, bc=bc)
                        w2 = step(w2)
                    got2 = np.asarray(unshard_state(jnp.asarray(
                        jax.block_until_ready(w2)), spec, GM))
                    assert np.array_equal(got2, np.asarray(ora)), \
                        (spec.name, bc.kind)
    # structural: no ppermute traffic on clamped faces
    clamped_step = make_distributed_step(mesh, HILBERT, local_M, g,
                                         bc=NEUMANN0)
    periodic_step = make_distributed_step(mesh, HILBERT, local_M, g)
    st = shard_state(jnp.asarray(data["gol"]), HILBERT, (2, 2, 2))
    perms_c = _collect_ppermute_perms(jax.make_jaxpr(clamped_step)(st).jaxpr)
    perms_p = _collect_ppermute_perms(jax.make_jaxpr(periodic_step)(st).jaxpr)
    assert len(perms_c) == len(perms_p) == 6  # two ppermutes per axis
    for perm in perms_c:   # open ring on a 2-device axis: only (0,1)/(1,0)
        assert len(perm) == 1 and abs(perm[0][0] - perm[0][1]) == 1, perm
    for perm in perms_p:   # periodic ring keeps the wrap link: n pairs
        assert len(perm) == 2, perm
    assert sum(len(p) for p in perms_c) < sum(len(p) for p in perms_p)
    return True


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs >=8 devices (multi-device CI job)")
def test_clamped_matrix_inprocess():
    assert _run_clamped_matrix()


_SUBPROC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, %r)
from test_boundary import _run_clamped_matrix
assert _run_clamped_matrix()
print("CLAMPED_MATRIX_OK")
"""


def test_clamped_matrix_subprocess():
    """Tier-1 form of the clamped acceptance matrix (8 host devices in a
    subprocess; the main pytest process keeps seeing 1 device)."""
    if jax.device_count() >= 8:
        pytest.skip("in-process variant already covers this")
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(here, "..", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROC % here],
                       capture_output=True, text=True, env=env, timeout=1200)
    assert "CLAMPED_MATRIX_OK" in r.stdout, r.stdout + r.stderr
