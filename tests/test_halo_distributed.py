"""Distributed halo exchange vs global oracle — 8 fake devices, subprocess.

Runs in a subprocess because XLA locks the host device count at first jax
init (the main pytest process must keep seeing 1 device for the smoke
tests — the dry-run has the same constraint, per the assignment)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import ROW_MAJOR, MORTON, HILBERT, apply_ordering, undo_ordering
from repro.stencil import make_stencil_mesh, make_distributed_step
from repro.kernels import ref as kref
from jax.sharding import PartitionSpec as P, NamedSharding

mesh = make_stencil_mesh((2, 2, 2))
local_M, g, GM = 8, %d, 16
rng = np.random.default_rng(3)
gcube = (rng.random((GM, GM, GM)) < 0.35).astype(np.float32)

for spec in (ROW_MAJOR, MORTON, HILBERT):
    st = np.zeros((2, 2, 2, local_M ** 3), np.float32)
    for a in range(2):
        for b in range(2):
            for c in range(2):
                loc = gcube[a*8:(a+1)*8, b*8:(b+1)*8, c*8:(c+1)*8]
                st[a, b, c] = np.asarray(apply_ordering(jnp.asarray(loc), spec))
    gs = jax.device_put(jnp.asarray(st), NamedSharding(mesh, P("dx", "dy", "dz")))
    step = make_distributed_step(mesh, spec, local_M, g)
    out = np.asarray(jax.block_until_ready(step(gs)))
    want = np.asarray(kref.gol3d_step_ref(jnp.asarray(gcube), g))
    got = np.zeros_like(gcube)
    for a in range(2):
        for b in range(2):
            for c in range(2):
                got[a*8:(a+1)*8, b*8:(b+1)*8, c*8:(c+1)*8] = np.asarray(
                    undo_ordering(jnp.asarray(out[a, b, c]), spec, local_M))
    assert (got == want).all(), spec.name
print("DISTRIBUTED_OK")
"""


@pytest.mark.parametrize("g", [1, 2])
def test_distributed_gol3d_matches_global_oracle(g):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT % g],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + r.stderr


def test_hilbert_device_permutation_adjacency():
    """mesh.py: consecutive devices in Hilbert order are torus-adjacent."""
    import numpy as np
    from repro.launch.mesh import _device_coords, hilbert_device_permutation

    class FakeDev:
        def __init__(self, i, coords):
            self.id = i
            self.coords = coords

    # an 4x4x4 torus
    devs = [FakeDev(i, tuple(np.unravel_index(i, (4, 4, 4)))) for i in range(64)]
    perm = hilbert_device_permutation(devs)
    coords = np.array([d.coords for d in perm])
    steps = np.abs(np.diff(coords, axis=0)).sum(1)
    assert steps.max() == 1  # every hop is a single ICI link
    assert sorted(d.id for d in perm) == list(range(64))
