"""benchmarks/diff.py — the perf-trajectory regression gate (satellite)."""

import json
import sys

import pytest

sys.path.insert(0, ".")
from benchmarks.diff import compare, main  # noqa: E402


def _rows(**us):
    return {k: {"name": k, "us_per_call": v,
                "derived": {"fused_bytes_per_substep": 1000}}
            for k, v in us.items()}


def test_compare_flags_only_threshold_crossings():
    old = _rows(a=1000.0, b=1000.0, c=10.0)
    new = _rows(a=1300.0, b=1100.0, c=40.0)
    reg, _ = compare(old, new, threshold=25.0, min_us=50.0, keys=[])
    assert len(reg) == 1 and reg[0].startswith("a:")  # b under 25%, c noise


def test_compare_floors_baseline_at_noise_floor():
    """A sub-noise-floor row can't flag on jitter, but blowing past the
    floored baseline by more than the threshold still registers."""
    old = _rows(fast=10.0)
    reg, _ = compare(old, _rows(fast=60.0), 25.0, min_us=50.0, keys=[])
    assert not reg  # within 25% of the 50 µs floor
    reg, _ = compare(old, _rows(fast=10000.0), 25.0, min_us=50.0, keys=[])
    assert len(reg) == 1  # a 1000x slowdown is not noise


def test_compare_derived_keys_and_row_churn():
    old = _rows(a=100.0, gone=100.0)
    new = _rows(a=100.0, fresh=100.0)
    new["a"]["derived"]["fused_bytes_per_substep"] = 2000
    reg, notes = compare(old, new, threshold=25.0, min_us=50.0,
                         keys=["fused_bytes_per_substep"])
    assert len(reg) == 1 and "fused_bytes_per_substep" in reg[0]
    assert any("gone" in n for n in notes)  # churn reported, never fatal
    assert any("fresh" in n for n in notes)


def test_compare_keys_threshold_pins_model_keys():
    """Satellite: the deterministic modelled-bytes keys gate at their own
    (tight) threshold while timings keep the noise-tolerant one."""
    old = _rows(a=100.0)
    new = _rows(a=150.0)  # +50% timing: under the 100% timing threshold
    new["a"]["derived"]["fused_bytes_per_substep"] = 1010  # +1% model drift
    reg, notes = compare(old, new, threshold=100.0, min_us=50.0,
                         keys=["fused_bytes_per_substep"], keys_threshold=0.0)
    assert len(reg) == 1 and "fused_bytes_per_substep" in reg[0]
    # a model *decrease* only notes (improvements never fail)
    new["a"]["derived"]["fused_bytes_per_substep"] = 900
    reg, notes = compare(old, new, threshold=100.0, min_us=50.0,
                         keys=["fused_bytes_per_substep"], keys_threshold=0.0)
    assert not reg and any("fused_bytes_per_substep" in n for n in notes)


def test_compare_notes_disappearing_pinned_key():
    """A still-present row that stops emitting a pinned key is reported
    as churn (visible, never fatal) instead of silently skipped."""
    old = _rows(a=100.0)
    new = _rows(a=100.0)
    del new["a"]["derived"]["fused_bytes_per_substep"]
    reg, notes = compare(old, new, threshold=100.0, min_us=50.0,
                         keys=["fused_bytes_per_substep"], keys_threshold=0.0)
    assert not reg
    assert any("disappeared" in n for n in notes)
    # a key absent on BOTH sides (schema predates it) stays silent
    del old["a"]["derived"]["fused_bytes_per_substep"]
    reg, notes = compare(old, new, threshold=100.0, min_us=50.0,
                         keys=["fused_bytes_per_substep"], keys_threshold=0.0)
    assert not reg and not notes


def test_main_keys_threshold_flag(tmp_path):
    rows_old = _rows(r=100.0)
    rows_new = _rows(r=100.0)
    rows_new["r"]["derived"]["fused_bytes_per_substep"] = 1001
    for name, rows in [("old.json", rows_old), ("new.json", rows_new)]:
        (tmp_path / name).write_text(json.dumps(
            {"git_rev": name, "rows": list(rows.values())}))
    argv = [str(tmp_path / "old.json"), str(tmp_path / "new.json"),
            "--threshold", "100", "--keys", "fused_bytes_per_substep"]
    assert main(argv) == 1                             # default pins exactly
    assert main(argv + ["--keys-threshold", "25"]) == 0


@pytest.mark.parametrize("new_us,code", [(100.0, 0), (300.0, 1)])
def test_main_exit_codes(tmp_path, new_us, code):
    for name, us in [("old.json", 100.0), ("new.json", new_us)]:
        (tmp_path / name).write_text(json.dumps(
            {"git_rev": name, "rows": list(_rows(r=us).values())}))
    assert main([str(tmp_path / "old.json"), str(tmp_path / "new.json"),
                 "--threshold", "25"]) == code
