"""Multi-field (C-channel) block store end-to-end (DESIGN.md §9).

Coverage layers, mirroring the single-field suites:

- store + registry units: blockize_fields/unblockize_fields round-trips
  against per-channel blockize, the wave rule's declared channels, and
  the rank/channel mismatch guards on kernel and oracle;
- resident matrix: the C=2 wave workload through ResidentPipeline —
  fused S-deep vs sequential bit-identity in both families, and (the
  wave rule is FMA-immune by construction) exact equality against the
  global sequential oracle ref.fields_step_ref across all four
  orderings and periodic + clamped + mixed boundaries;
- plan(): the VMEM budget carries the ×C working set, so wave plans
  never exceed the budget and shrink under tight limits;
- bytes model: every accounting helper's ``fields`` factor is exactly
  ×C, the multifield benchmark rows carry precisely the helpers'
  numbers, and run.py stamps ``fields`` into the JSON schema;
- exchange: the C-channel shell exchange on a 1×1×1 mesh equals the
  per-channel pad, packed through one set of messages;
- the ≥8-device wave acceptance matrix: DistributedPipeline S-deep vs S
  sequential make_distributed_step rounds, bit-identical, for all four
  orderings × {periodic, neumann0}, plus the global-oracle column —
  in-process on the multi-device CI job, subprocess under tier-1.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (COLUMN_MAJOR, HILBERT, MORTON, NEUMANN0, ROW_MAJOR,
                        blockize, blockize_fields, dirichlet, mixed,
                        unblockize_fields)
from repro.core.neighbors import neighbor_table_device
from repro.kernels import ref as kref
from repro.kernels.ops import uniform_weights
from repro.kernels.rules import RULES, get_rule
from repro.kernels.stencil3d import stencil_step_fused
from repro.stencil import (DistributedPipeline, ResidentPipeline,
                           distributed_bytes_per_step, exchange_bytes_per_step,
                           exchange_items_per_exchange, fused_items_per_launch,
                           fused_vmem_bytes, make_stencil_mesh,
                           resident_bytes_per_step)

rng = np.random.default_rng(31)

ORDERINGS = (ROW_MAJOR, COLUMN_MAJOR, MORTON, HILBERT)
M, T, G = 16, 8, 1


def _fields(C=2, M_=M):
    return jnp.asarray(rng.normal(size=(C, M_, M_, M_)).astype(np.float32))


def _oracle_run(fields, g, steps, bc="periodic"):
    w = uniform_weights(g)
    want = fields
    for _ in range(steps):
        want = kref.fields_step_ref(want, w, g, rule="wave", bc=bc)
    return np.asarray(want)


# ------------------------------------------------------- store + rule units
def test_wave_rule_registered():
    assert RULES["wave"].channels == 2
    assert get_rule("wave") is RULES["wave"]
    for name in ("gol", "jacobi", "identity"):
        assert get_rule(name).channels == 1


def test_blockize_fields_roundtrip_shares_block_permutation():
    fields = _fields()
    for kind in ("morton", "hilbert", "row_major"):
        store = blockize_fields(fields, T, kind=kind)
        assert store.shape == (2, (M // T) ** 3, T, T, T)
        # channel c's blocks are exactly blockize of channel c — one
        # shared permutation, no per-channel layout drift
        for c in range(2):
            np.testing.assert_array_equal(
                np.asarray(store[c]),
                np.asarray(blockize(fields[c], T, kind=kind)))
        back = unblockize_fields(store, M, kind=kind)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(fields))
    # 3-D input promotes to C=1
    one = blockize_fields(fields[0], T, kind="morton")
    assert one.shape == (1, (M // T) ** 3, T, T, T)


def test_channel_mismatch_rejected():
    w = uniform_weights(G)
    nbr = neighbor_table_device("morton", M // T)
    scalar = blockize(_fields()[0], T, kind="morton")
    stacked = blockize_fields(_fields(), T, kind="morton")
    with pytest.raises(ValueError):  # wave needs the stacked store
        stencil_step_fused(scalar, w, nbr, g=G, S=1, rule="wave")
    with pytest.raises(ValueError):  # gol is C=1
        stencil_step_fused(stacked, w, nbr, g=G, S=1, rule="gol")
    with pytest.raises(ValueError):
        kref.stencil_fused_ref(scalar, w, nbr, S=1, rule="wave")
    with pytest.raises(ValueError):
        kref.fields_step_ref(_fields(3), w, G, rule="wave")
    with pytest.raises(ValueError):  # pipelines refuse mismatched state
        ResidentPipeline(M=M, T=T, g=G, rule="wave").run(_fields()[0], 1)


def test_wave_leapfrog_is_stable():
    """κ·λ_max < 4: the leapfrog oscillates, state stays bounded — the
    property that makes long fused runs meaningful (DESIGN.md §9)."""
    fields = _fields()
    out = np.asarray(_oracle_run(fields, G, 32))
    assert np.isfinite(out).all()
    assert np.abs(out).max() < 64 * np.abs(np.asarray(fields)).max()


# ----------------------------------------------------------- resident matrix
@pytest.mark.parametrize("spec_kind", ["row_major", "column_major",
                                       "morton", "hilbert"])
@pytest.mark.parametrize("S", [2, 4])
def test_resident_wave_fused_matches_sequential_and_oracle(spec_kind, S):
    """Acceptance: the C=2 wave rule through ResidentPipeline — fused
    S-deep (kernel and jnp families) == S=1 sequential == the global
    sequential jnp oracle, bit-identical (f32), for every ordering."""
    fields = _fields()
    deep = ResidentPipeline(M=M, T=T, g=G, kind=spec_kind, S=S, rule="wave",
                            use_kernel=True)
    seq = ResidentPipeline(M=M, T=T, g=G, kind=spec_kind, S=1, rule="wave")
    a = np.asarray(deep.run(fields, S))
    np.testing.assert_array_equal(a, np.asarray(seq.run(fields, S)))
    ora = ResidentPipeline(M=M, T=T, g=G, kind=spec_kind, S=S, rule="wave")
    np.testing.assert_array_equal(a, np.asarray(ora.run(fields, S)))
    np.testing.assert_array_equal(a, _oracle_run(fields, G, S))


@pytest.mark.parametrize("bc", [NEUMANN0, dirichlet(0.5), mixed(k=NEUMANN0)],
                         ids=lambda b: b.kind)
def test_resident_wave_clamped_and_mixed(bc):
    """Clamped + per-face mixed contracts on the multi-field store: the
    per-substep ghost refresh applies to every channel alike and stays
    bit-identical to the padded-fields oracle (DESIGN.md §8–§9)."""
    fields = _fields()
    S = 4
    deep = ResidentPipeline(M=M, T=T, g=G, kind="hilbert", S=S, rule="wave",
                            bc=bc, use_kernel=True)
    ora = ResidentPipeline(M=M, T=T, g=G, kind="hilbert", S=S, rule="wave",
                          bc=bc)
    a = np.asarray(deep.run(fields, S))
    np.testing.assert_array_equal(a, np.asarray(ora.run(fields, S)))
    np.testing.assert_array_equal(a, _oracle_run(fields, G, S, bc=bc))


# ------------------------------------------------------- plan() + VMEM model
def test_plan_budgets_vmem_for_C_windows():
    """The autotuner's working set carries the ×C factor: wave plans fit
    the budget with C=2 windows live, and a tight budget forces a
    smaller window than the C=1 plan gets away with."""
    for M_, lim in [(32, 256 * 1024), (64, 8 * 2 ** 20)]:
        pipe = ResidentPipeline.plan(M_, g=1, rule="wave", vmem_limit=lim)
        assert pipe.channels == 2
        assert fused_vmem_bytes(pipe.T, 1, pipe.S, fields=2) <= lim
        assert pipe.vmem_bytes() == fused_vmem_bytes(pipe.T, 1, pipe.S,
                                                     fields=2)
    # same tight budget: the wave plan either matches the C=1 pick or
    # was forced off it because two windows no longer fit
    lim = 96 * 1024
    one = ResidentPipeline.plan(64, g=1, rule="gol", vmem_limit=lim)
    two = ResidentPipeline.plan(64, g=1, rule="wave", vmem_limit=lim)
    assert fused_vmem_bytes(two.T, 1, two.S, fields=2) <= lim
    assert (two.T, two.S) == (one.T, one.S) or \
        fused_vmem_bytes(one.T, 1, one.S, fields=2) > lim
    # an impossible budget still raises
    with pytest.raises(ValueError):
        ResidentPipeline.plan(64, g=1, rule="wave", vmem_limit=256)


def test_plan_wave_runs_correctly():
    pipe = ResidentPipeline.plan(M, g=G, kind="morton", rule="wave",
                                 vmem_limit=256 * 1024)
    fields = _fields()
    got = np.asarray(pipe.run(fields, 3))
    np.testing.assert_array_equal(got, _oracle_run(fields, G, 3))


# --------------------------------------------------- bytes model + benchmarks
def test_bytes_model_fields_factor_is_exactly_C():
    """Acceptance: modelled HBM and ICI both scale by exactly ×C — the
    multi-field store adds payload, never overhead."""
    for C in (2, 3, 4):
        assert fused_items_per_launch(64, 8, 1, 4, fields=C) == \
            C * fused_items_per_launch(64, 8, 1, 4)
        assert resident_bytes_per_step(64, 8, 1, 10, S=4, fields=C) == \
            pytest.approx(C * resident_bytes_per_step(64, 8, 1, 10, S=4))
        assert exchange_items_per_exchange(16, 1, 4, fields=C) == \
            C * exchange_items_per_exchange(16, 1, 4)
        assert exchange_bytes_per_step(16, 1, 4, fields=C) == \
            pytest.approx(C * exchange_bytes_per_step(16, 1, 4))
        assert distributed_bytes_per_step(16, 8, 1, 10, S=4, fields=C) == \
            pytest.approx(C * distributed_bytes_per_step(16, 8, 1, 10, S=4))
    # clamped exchange composes with fields
    assert exchange_items_per_exchange(
        16, 1, 4, bc=NEUMANN0, procs=(2, 2, 2), coords=(0, 0, 0),
        fields=2) == 2 * exchange_items_per_exchange(
        16, 1, 4, bc=NEUMANN0, procs=(2, 2, 2), coords=(0, 0, 0))


def test_multifield_benchmark_rows_share_accounting():
    """Satellite: the multifield rows carry exactly the pipeline model's
    ×C numbers, and run.py stamps ``fields`` into the JSON schema."""
    sys.path.insert(0, ".")
    from benchmarks.run import _parse_derived
    from benchmarks.stencil_update import WAVE_FIELDS, multifield_derived

    M_, T_, g, S, K = 32, 8, 1, 4, 10
    d = _parse_derived(multifield_derived(M_, T_, g, S, K))
    assert d["fields"] == WAVE_FIELDS == 2
    assert d["fused_bytes_per_substep"] == round(
        resident_bytes_per_step(M_, T_, g, K, S=S, fields=2))
    assert d["fused_bytes_per_field_substep"] == round(
        resident_bytes_per_step(M_, T_, g, K, S=S, fields=2) / 2)
    assert d["fused_vs_single_field"] == pytest.approx(2.0)
    assert d["ici_bytes_per_step"] == round(
        exchange_bytes_per_step(M_, g, S, fields=2))
    assert d["distributed_bytes_per_step"] == round(
        distributed_bytes_per_step(M_, T_, g, K, S=S, fields=2))
    # run.py --json: fields is stamped top-level, defaulting to 1 for
    # rows that predate the multi-field store
    assert int(_parse_derived("fields=2;a=1").get("fields", 1)) == 2
    assert int(_parse_derived("a=1").get("fields", 1)) == 1


def test_pipeline_wave_bytes_accessors_carry_C():
    pipe = ResidentPipeline(M=32, T=8, g=1, S=4, rule="wave")
    assert pipe.bytes_per_step(10) == resident_bytes_per_step(
        32, 8, 1, 10, S=4, fields=2)
    mesh = make_stencil_mesh((1, 1, 1))
    dp = DistributedPipeline(mesh=mesh, spec=HILBERT, M=16, T=8, g=1, S=2,
                             rule="wave")
    assert dp.channels == 2
    assert dp.exchange_bytes_per_step() == exchange_bytes_per_step(
        16, 1, 2, fields=2)
    assert dp.bytes_per_step(10) == distributed_bytes_per_step(
        16, 8, 1, 10, S=2, fields=2)


# ----------------------------------------- exchange + 1×1×1 mesh (in-process)
def test_exchange_shell_multifield_matches_per_channel_pad():
    """The C-channel shell exchange packs every channel through one set
    of messages and equals the per-channel wrap pad on a self-mesh."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.stencil.halo import exchange_shell

    M_, T_, h = 16, 8, 2
    mesh = make_stencil_mesh((1, 1, 1))
    fields = np.asarray(_fields(2, M_))
    store = blockize_fields(jnp.asarray(fields), T_, kind="hilbert")
    fn = shard_map(
        lambda st: exchange_shell(st.reshape(2, -1), "hilbert", M_, T_, h),
        mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    slabs = [np.asarray(s) for s in fn(store)]
    e = M_ + 2 * h
    for c in range(2):
        xp = np.pad(fields[c], h, mode="wrap")
        np.testing.assert_array_equal(slabs[0][c], xp[:h, h:h + M_, h:h + M_])
        np.testing.assert_array_equal(slabs[1][c],
                                      xp[e - h:, h:h + M_, h:h + M_])
        np.testing.assert_array_equal(slabs[4][c], xp[:, :, :h])
        np.testing.assert_array_equal(slabs[5][c], xp[:, :, e - h:])


@pytest.mark.parametrize("use_kernel", [False, True])
def test_shard_substeps_wave_self_wrap_matches_oracle(use_kernel):
    """One deep C=2 round on a 1×1×1 mesh == S global wave steps."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.stencil.halo import shard_substeps

    S = 4
    mesh = make_stencil_mesh((1, 1, 1))
    fields = _fields()
    store = blockize_fields(fields, T, kind="morton")
    fn = shard_map(
        lambda st: shard_substeps(st, kind="morton", M=M, g=G, S=S,
                                  rule="wave", use_kernel=use_kernel),
        mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    got = np.asarray(unblockize_fields(fn(store), M, kind="morton"))
    np.testing.assert_array_equal(got, _oracle_run(fields, G, S))


# ------------------------------------------------- acceptance matrix (≥ 8 dev)
def _run_wave_matrix():
    """Acceptance (DESIGN.md §9): the C=2 wave DistributedPipeline S-deep
    run == S sequential make_distributed_step rounds, bit-identical, for
    all four orderings × {periodic, neumann0} × S ∈ {1, 2, 4}; the
    periodic hilbert column also equals the global sequential oracle
    through run_cube (shard → K deep rounds → gather).
    """
    from repro.stencil import make_distributed_step, shard_state

    mesh = make_stencil_mesh((2, 2, 2))
    local_M, g, GM = 8, 1, 16
    r = np.random.default_rng(9)
    gf = jnp.asarray(r.normal(size=(2, GM, GM, GM)).astype(np.float32))
    for spec in ORDERINGS:
        for bc in ("periodic", NEUMANN0):
            st0 = shard_state(gf, spec, (2, 2, 2))
            assert st0.shape == (2, 2, 2, 2, local_M ** 3)
            step = make_distributed_step(mesh, spec, local_M, g, rule="wave",
                                         bc=bc)
            for S in (1, 2, 4):
                pipe = DistributedPipeline(mesh=mesh, spec=spec, M=local_M,
                                           T=8, g=g, S=S, rule="wave", bc=bc)
                got = np.asarray(jax.block_until_ready(pipe.run(st0, S)))
                want = st0
                for _ in range(S):
                    want = step(want)
                want = np.asarray(jax.block_until_ready(want))
                assert np.array_equal(got, want), (spec.name, str(bc), S)
    # the global-oracle column (round trip through shard/unshard)
    w = uniform_weights(g)
    want = gf
    for _ in range(4):
        want = kref.fields_step_ref(want, w, g, rule="wave")
    pipe = DistributedPipeline(mesh=mesh, spec=HILBERT, M=local_M, g=g, S=4,
                               rule="wave")
    got = np.asarray(pipe.run_cube(gf, 4))
    assert got.shape == (2, GM, GM, GM)
    assert np.array_equal(got, np.asarray(want))
    return True


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs >=8 devices (multi-device CI job)")
def test_wave_matrix_inprocess():
    assert _run_wave_matrix()


_SUBPROC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, %r)
from test_multifield import _run_wave_matrix
assert _run_wave_matrix()
print("WAVE_MATRIX_OK")
"""


def test_wave_matrix_subprocess():
    """Tier-1 form of the 8-device distributed wave acceptance test."""
    if jax.device_count() >= 8:
        pytest.skip("in-process variant already covers this")
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(here, "..", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROC % here],
                       capture_output=True, text=True, env=env, timeout=1200)
    assert "WAVE_MATRIX_OK" in r.stdout, r.stdout + r.stderr
