"""Launcher drivers end-to-end (subprocess smoke: train, serve, elastic)."""

import os
import subprocess
import sys

import pytest


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-m", *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_train_driver_smoke(tmp_path):
    r = _run(["repro.launch.train", "--arch", "smollm-360m", "--smoke",
              "--steps", "3", "--batch", "2", "--seq", "32",
              "--ckpt-dir", str(tmp_path / "ck")])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "loss" in r.stdout


def test_serve_driver_smoke():
    r = _run(["repro.launch.serve", "--arch", "gemma3-1b", "--smoke",
              "--batch", "2", "--prompt-len", "4", "--new-tokens", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "tok/s" in r.stdout


def test_elastic_driver():
    r = _run(["repro.launch.elastic", "--devices", "8",
              "--from-shape", "4,2", "--to-shape", "2,2"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "params bit-exact" in r.stdout
    assert "[elastic] OK" in r.stdout


def test_train_driver_rejects_stub_archs(tmp_path):
    r = _run(["repro.launch.train", "--arch", "whisper-small", "--smoke",
              "--steps", "1", "--ckpt-dir", str(tmp_path / "ck")])
    assert r.returncode != 0  # directed to the family-specific driver
