"""End-to-end behaviour tests: the paper's claims, through the public API."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HILBERT, MORTON, ROW_MAJOR
from repro.stencil import Gol3d, Gol3dConfig


def test_gol3d_result_is_ordering_invariant():
    """The ordering changes LAYOUT, never semantics: all three orderings
    (and both kernel/jnp paths) produce identical evolutions."""
    finals = []
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        for use_kernel in (False, True):
            app = Gol3d(Gol3dConfig(M=16, g=1, ordering=spec, block_T=4,
                                    seed=3, use_kernel=use_kernel))
            app.run(4)
            finals.append(np.asarray(app.cube))
    for f in finals[1:]:
        np.testing.assert_array_equal(finals[0], f)


def test_gol3d_matches_reference_run():
    app = Gol3d(Gol3dConfig(M=16, g=2, ordering=MORTON, block_T=4, seed=5))
    ref_final = np.asarray(app.reference_run(3))
    app.run(3)
    np.testing.assert_array_equal(np.asarray(app.cube), ref_final)


def test_gol3d_nontrivial_evolution():
    """Guard against degenerate all-dead/all-alive dynamics."""
    app = Gol3d(Gol3dConfig(M=16, g=1, ordering=HILBERT, block_T=4, seed=0,
                            density=0.3))
    before = float(np.asarray(app.cube).mean())
    app.run(2)
    after = float(np.asarray(app.cube).mean())
    assert 0.0 < after < 1.0
    assert after != before


def test_paper_headline_claim():
    """The paper's net claim (§6.1): SFC layouts trade a small loss on the
    contiguous faces for a large win on the strided faces, for a
    significant NET data-movement benefit. Score all six faces with the
    cache model and compare totals."""
    from repro.core import surface_cache_misses
    M, g, b, c = 32, 1, 8, 64
    total = {}
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        total[spec.name] = sum(
            surface_cache_misses(spec, M, g, b, c, f)
            for f in ("k0", "k1", "i0", "i1", "j0", "j1"))
    assert total["morton"] < total["row_major"]
    assert total["hilbert"] < total["row_major"]


def test_serve_greedy_decode_end_to_end():
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import greedy_decode

    cfg = dataclasses.replace(
        get_config("smollm-360m"), n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=1, head_dim=32, d_ff=128, vocab=256,
        activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(np.arange(8, dtype=np.int32).reshape(2, 4))
    out = greedy_decode(model, params, prompts, n_new=6, max_len=12)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())
