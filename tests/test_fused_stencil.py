"""Temporal-blocked fused stencil (DESIGN.md §4) + PR-2 satellites.

Equivalence discipline (mirrors PR-1): bit-identity is asserted within
an implementation family — the fused S-substep kernel against S
sequential launches of the same kernel, and the fused jnp oracle
against S sequential oracle steps. Across families (Pallas interpret vs
jnp) XLA's FMA contraction can differ in the last ulp for arbitrary f32
data, so cross-family checks are exact for gol (integer-valued sums)
and allclose for jacobi.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MORTON, blockize, blockize_fields
from repro.core.neighbors import neighbor_table_device
from repro.kernels import ref
from repro.kernels.ops import uniform_weights
from repro.kernels.rules import RULES, get_rule
from repro.kernels.stencil3d import stencil_step_fused, stencil_sum_resident
from repro.stencil import Gol3d, Gol3dConfig
from repro.stencil.pipeline import (VMEM_BUDGET_BYTES, ResidentPipeline,
                                    fused_items_per_launch, fused_vmem_bytes,
                                    repack_bytes_per_step,
                                    repack_items_per_step,
                                    resident_bytes_per_step,
                                    resident_unfused_bytes_per_step,
                                    resident_unfused_items_per_step)

rng = np.random.default_rng(11)

KINDS = ("row_major", "column_major", "morton", "hilbert")
M, T, G = 16, 8, 1


def _store(kind, rule):
    C = get_rule(rule).channels
    if rule == "gol":
        cube = (rng.random((M, M, M)) < 0.3).astype(np.float32)
    elif C == 1:
        cube = rng.normal(size=(M, M, M)).astype(np.float32)
    else:  # stacked multi-field state (DESIGN.md §9)
        fields = rng.normal(size=(C, M, M, M)).astype(np.float32)
        return blockize_fields(jnp.asarray(fields), T, kind=kind)
    return blockize(jnp.asarray(cube), T, kind=kind)


def _seq_kernel(store, w, nbr, steps, rule):
    for _ in range(steps):
        store = stencil_step_fused(store, w, nbr, g=G, S=1, rule=rule)
    return store


# ------------------------------------------------------- fused bit-identity
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("S", [1, 2, 4])
@pytest.mark.parametrize("rule", ["gol", "jacobi", "wave"])
def test_fused_kernel_matches_sequential_seed_steps(kind, S, rule):
    """One fused S-substep launch == S sequential seed-step launches —
    the kernel-family matrix, now spanning the multi-field C=2 wave
    store (DESIGN.md §9) next to the scalar rules."""
    w = uniform_weights(G)
    nbr = neighbor_table_device(kind, M // T)
    store = _store(kind, rule)
    r = get_rule(rule)
    fused = stencil_step_fused(store, w, nbr, g=G, S=S, rule=rule)
    seq = _seq_kernel(store, w, nbr, S, rule)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))
    # the jnp oracle of the fused form matches its own sequential form...
    oracle = ref.stencil_fused_ref(store, w, nbr, S=S, rule=rule)
    oseq = store
    for _ in range(S):
        if r.channels == 1:
            neigh = ref.stencil_sum_resident_ref(oseq, w, nbr)
        else:  # per-channel tap sums of the stacked store
            neigh = jnp.stack([ref.stencil_sum_resident_ref(oseq[c], w, nbr)
                               for c in range(r.channels)])
        oseq = r.apply(oseq.astype(jnp.float32), neigh, G).astype(store.dtype)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(oseq))
    # ...and the kernel cross-family: exact for gol (integer sums) and
    # wave (FMA-immune by construction), allclose for jacobi (divide)
    if rule == "jacobi":
        np.testing.assert_allclose(np.asarray(fused), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(oracle))


def test_fused_identity_rule_is_raw_stencil_sum():
    """rule="identity", S=1 reproduces the PR-1 resident tap-sum kernel."""
    w = uniform_weights(G)
    nbr = neighbor_table_device("morton", M // T)
    store = _store("morton", "jacobi")
    a = stencil_step_fused(store, w, nbr, g=G, S=1, rule="identity")
    b = stencil_sum_resident(store, w, nbr, g=G)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_kernel_rejects_bad_S():
    store = jnp.zeros((8, 8, 8, 8), jnp.float32)
    nbr = neighbor_table_device("morton", 2)
    w = uniform_weights(1)
    with pytest.raises(ValueError):
        stencil_step_fused(store, w, nbr, g=1, S=3, rule="gol")  # 3 ∤ 8
    with pytest.raises(ValueError):
        stencil_step_fused(store, w, nbr, g=1, S=16, rule="gol")  # 16 > T
    with pytest.raises(ValueError):
        stencil_step_fused(store, w, nbr, g=1, S=2, rule="nope")


def test_rules_registry():
    assert set(RULES) >= {"gol", "jacobi", "identity"}
    assert get_rule("gol") is RULES["gol"]
    assert get_rule(RULES["jacobi"]) is RULES["jacobi"]
    with pytest.raises(ValueError):
        get_rule("unknown-rule")


# ------------------------------------------------------------- the pipeline
@pytest.mark.parametrize("n_steps", [3, 7, 10])
def test_pipeline_S_matches_single_step_pipeline(n_steps):
    """Fused S=4 kernel pipeline == S=1 oracle pipeline, incl. K % S
    remainders (7 = 1 full launch + 3 single-step tail since 3·g ∤ T)."""
    cube = jnp.asarray((rng.random((M, M, M)) < 0.3).astype(np.float32))
    base = ResidentPipeline(M=M, T=T, g=G, kind="hilbert", S=1)
    fused = ResidentPipeline(M=M, T=T, g=G, kind="hilbert", S=4,
                             use_kernel=True)
    np.testing.assert_array_equal(np.asarray(base.run(cube, n_steps)),
                                  np.asarray(fused.run(cube, n_steps)))


def test_pipeline_S_matches_oracle_reference():
    """Fused S through Gol3d (substeps knob) == canonical cube oracle."""
    app = Gol3d(Gol3dConfig(M=M, g=G, ordering=MORTON, block_T=T, substeps=2))
    want = app.reference_run(4)
    app.run_resident(4)
    np.testing.assert_array_equal(np.asarray(app.cube), np.asarray(want))


def test_pipeline_rejects_bad_S():
    with pytest.raises(ValueError):
        ResidentPipeline(M=16, T=8, g=1, S=3)
    with pytest.raises(ValueError):
        ResidentPipeline(M=16, T=8, g=2, S=8)


def test_pipeline_jacobi_rule():
    """The same fused driver serves the jacobi workload (new-rule path)."""
    cube = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    a = ResidentPipeline(M=M, T=T, g=G, rule="jacobi", S=2,
                         use_kernel=True).run(cube, 4)
    b = ResidentPipeline(M=M, T=T, g=G, rule="jacobi", S=1,
                         use_kernel=True).run(cube, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------- autotuner + VMEM model
def test_plan_respects_vmem_budget():
    """Acceptance: the autotuned (T, S) fits the modelled VMEM budget."""
    for M_, g in [(32, 1), (64, 1), (64, 2), (128, 1)]:
        pipe = ResidentPipeline.plan(M_, g=g)
        assert fused_vmem_bytes(pipe.T, g, pipe.S) <= VMEM_BUDGET_BYTES
        assert pipe._valid_S(pipe.S) and M_ % pipe.T == 0
        # the plan never models more traffic than the default (T=8, S=1)
        assert (pipe.bytes_per_step(10)
                <= resident_bytes_per_step(M_, 8, g, 10, S=1))
    # a tight budget forces a smaller window, and still fits
    tight = ResidentPipeline.plan(64, g=1, vmem_limit=64 * 1024)
    assert fused_vmem_bytes(tight.T, 1, tight.S) <= 64 * 1024
    with pytest.raises(ValueError):
        ResidentPipeline.plan(64, g=1, vmem_limit=64)


def test_plan_pipeline_runs_correctly():
    pipe = ResidentPipeline.plan(M, g=G, kind="morton",
                                 vmem_limit=256 * 1024)
    cube = jnp.asarray((rng.random((M, M, M)) < 0.3).astype(np.float32))
    got = pipe.run(cube, 5)
    want = cube
    for _ in range(5):
        want = ref.gol3d_step_ref(want, G)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------- bytes model + benchmarks
def test_fused_bytes_model_acceptance():
    """Acceptance: at (M=64, T=8, g=1, S=4) the fused path models ≥ 2×
    fewer HBM bytes/substep than the PR-1 unfused resident path."""
    fused = resident_bytes_per_step(64, 8, 1, 10, S=4)
    unfused = resident_unfused_bytes_per_step(64, 8, 1, 10)
    assert fused * 2 <= unfused
    # and still strictly beats repack at every depth
    for S in (1, 2, 4, 8):
        assert resident_bytes_per_step(64, 8, 1, 10, S=S) < \
            repack_bytes_per_step(64, 8, 1)


def test_bytes_model_has_interior_optimum_in_S():
    """At fixed T the per-substep window cost (T+2·S·g)³/S first falls
    (launch overheads amortise) then rises (window inflation wins):
    the autotuner exists because S is a real knob, not 'always more'."""
    b = {S: resident_bytes_per_step(64, 8, 1, 100, S=S) for S in (1, 2, 4, 8)}
    assert b[2] < b[1]          # fusing helps...
    assert b[8] > b[2]          # ...but too-deep blocking pays more halo
    # plan() at a budget that admits T=8 picks the interior optimum, not S=1
    pipe = ResidentPipeline.plan(64, g=1, n_steps=100, vmem_limit=64 * 1024)
    assert pipe.bytes_per_step(100) <= min(b.values())


def test_benchmark_rows_share_accounting():
    """Satellite: stencil_update rows carry exactly the pipeline model's
    numbers — one accounting helper across model and benchmarks."""
    import sys
    sys.path.insert(0, ".")
    from benchmarks.run import _parse_derived
    from benchmarks.stencil_update import resident_derived

    M_, T_, g, S, K = 64, 8, 1, 4, 10
    d = _parse_derived(resident_derived(M_, T_, g, S, K))
    assert d["fused_bytes_per_substep"] == round(
        resident_bytes_per_step(M_, T_, g, K, S=S))
    assert d["unfused_bytes_per_step"] == round(
        resident_unfused_bytes_per_step(M_, T_, g, K))
    assert d["repack_bytes_per_step"] == round(repack_bytes_per_step(M_, T_, g))
    assert d["fused_vs_unfused"] >= 2.0  # the acceptance ratio, as reported
    # distributed totals ride the same helpers (DESIGN.md §7)
    from repro.stencil import (distributed_bytes_per_step,
                               exchange_bytes_per_step)
    assert d["ici_bytes_per_step"] == round(exchange_bytes_per_step(M_, g, S))
    assert d["distributed_bytes_per_step"] == round(
        distributed_bytes_per_step(M_, T_, g, K, S=S))
    assert d["distributed_bytes_per_step"] == round(
        d["fused_bytes_per_substep"] + exchange_bytes_per_step(M_, g, S))
    # items helpers and bytes helpers agree (itemsize=4)
    assert repack_bytes_per_step(M_, T_, g) == 4 * repack_items_per_step(M_, T_, g)
    assert fused_items_per_launch(M_, T_, g, 1) + 2 * (M_ // T_) ** 3 * T_ ** 3 \
        == resident_unfused_items_per_step(M_, T_, g)


# ----------------------------------------------------------- cache satellites
def test_device_constant_lru_eviction():
    """Satellite: a hit moves the entry to the back, so hot tables
    survive a sweep of one-off keys that would evict them under FIFO."""
    from repro.core import layout

    cap = layout._DEVICE_CONSTANTS_CAP
    cache = layout._DEVICE_CONSTANTS
    hot = ("test-lru-hot",)
    layout.device_constant(hot, lambda: np.zeros(1, np.int32))
    for i in range(cap):  # a full sweep: FIFO would now have evicted `hot`
        if i == cap // 2:
            layout.device_constant(hot, lambda: np.zeros(1, np.int32))
        layout.device_constant(("test-lru-sweep", i),
                               lambda: np.zeros(1, np.int32))
    assert hot in cache
    assert ("test-lru-sweep", 0) not in cache  # untouched entries do rotate out
    assert len(cache) <= cap
    for k in [hot] + [("test-lru-sweep", i) for i in range(cap)]:
        cache.pop(k, None)


def test_surface_row_plan_cached():
    """Satellite: pack_surface memoises the unique/searchsorted row plan
    on (spec, M, g, face, line); repeated packs reuse the same arrays."""
    from repro.kernels import ops

    M_, g, line = 16, 1, 8
    key = ((MORTON, M_, g, "k0"), line)
    ops._ROW_PLANS.pop(key, None)
    cube = jnp.asarray(rng.normal(size=(M_, M_, M_)).astype(np.float32))
    from repro.core import apply_ordering
    data = apply_ordering(cube, MORTON)
    a = ops.pack_surface(data, MORTON, M_, g, "k0", use_kernel=True, line=line)
    assert key in ops._ROW_PLANS
    plan1 = ops._ROW_PLANS[key]
    b = ops.pack_surface(data, MORTON, M_, g, "k0", use_kernel=True, line=line)
    assert ops._ROW_PLANS[key] is plan1  # reused, not recomputed
    assert not plan1[0].flags.writeable
    ref_buf = ops.pack_surface(data, MORTON, M_, g, "k0", use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ref_buf))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(ref_buf))
