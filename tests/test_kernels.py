"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HILBERT, MORTON, ROW_MAJOR, apply_ordering
from repro.kernels import ref
from repro.kernels.flash_attn import build_schedule, flash_attention_fwd
from repro.kernels.ops import (flash_attention, gol3d_step, pack_surface,
                               sfc_gather_take, unpack_surface, _fold_gqa)
from repro.kernels.sfc_gather import gather_rows
from repro.kernels.stencil3d import stencil_sum_blocks

rng = np.random.default_rng(42)


# ----------------------------------------------------------------- stencil
@pytest.mark.parametrize("g,T", [(1, 4), (1, 8), (2, 4), (3, 2)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_stencil_kernel_allclose(g, T, dtype):
    W = T + 2 * g
    blocks = jnp.asarray(rng.normal(size=(6, W, W, W)).astype(np.float32)
                         ).astype(dtype)
    w = jnp.asarray(rng.normal(size=(2 * g + 1,) * 3).astype(np.float32))
    out_k = stencil_sum_blocks(blocks, w, g=g)
    out_r = ref.stencil_sum_ref(blocks, w)
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


def test_gol3d_kernel_matches_canonical():
    cube = jnp.asarray((rng.random((16, 16, 16)) < 0.3).astype(np.float32))
    for g in (1, 2):
        for kind in ("morton", "hilbert"):
            a = gol3d_step(cube, g=g, T=4, block_kind=kind, use_kernel=True)
            b = ref.gol3d_step_ref(cube, g=g)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ gather
@pytest.mark.parametrize("n,L,r", [(32, 16, 10), (8, 128, 8), (64, 8, 64)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_gather_rows_allclose(n, L, r, dtype):
    src = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32)).astype(dtype)
    idx = jnp.asarray(rng.integers(0, n, size=(r,)).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(gather_rows(src, idx)),
                                  np.asarray(ref.gather_rows_ref(src, idx)))


def test_sfc_gather_take_exact():
    data = jnp.asarray(rng.normal(size=(4096,)).astype(np.float32))
    idx = rng.choice(4096, size=777, replace=False)
    idx.sort()
    a = sfc_gather_take(data, idx, line=64, use_kernel=True)
    b = jnp.take(data, jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("spec", [ROW_MAJOR, MORTON, HILBERT],
                         ids=lambda s: s.name)
def test_pack_unpack_roundtrip(spec):
    M, g = 16, 1
    cube = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    data = apply_ordering(cube, spec)
    for face in ("k0", "j1", "i0"):
        buf_k = pack_surface(data, spec, M, g, face, use_kernel=True, line=8)
        buf_r = pack_surface(data, spec, M, g, face, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(buf_k), np.asarray(buf_r))
        back = unpack_surface(data, buf_r, spec, M, g, face)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(data))


# -------------------------------------------------------------- flash attn
@pytest.mark.parametrize("schedule", ["row_major", "morton", "hilbert"])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_fwd_allclose(schedule, causal):
    for (BH, Sq, Sk, D) in [(2, 64, 64, 16), (1, 128, 128, 32), (2, 32, 128, 16)]:
        q = jnp.asarray(rng.normal(size=(BH, Sq, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BH, Sk, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BH, Sk, D)).astype(np.float32))
        o_k = flash_attention_fwd(q, k, v, causal=causal, block_q=16,
                                  block_k=16, schedule=schedule)
        o_r = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=2e-4, atol=2e-4)


def test_flash_fwd_bf16():
    q = jnp.asarray(rng.normal(size=(2, 64, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 64, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 64, 32))).astype(jnp.bfloat16)
    o_k = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32)
    o_r = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), rtol=0.1, atol=0.1)


def test_flash_gqa_grad_matches_ref():
    q = jnp.asarray(rng.normal(size=(2, 4, 32, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 32, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 32, 8)).astype(np.float32))

    def loss_k(q, k, v):
        return flash_attention(q, k, v, True, "morton", 16, 16).sum()

    def loss_r(q, k, v):
        qf, kf, vf = _fold_gqa(q, k, v)
        return ref.attention_ref(qf, kf, vf, causal=True).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_schedule_covers_causal_cells():
    for kind in ("row_major", "morton", "hilbert"):
        iq, ik = build_schedule(4, 4, causal=True, block_q=16, block_k=16,
                                kind=kind)
        cells = set(zip(iq.tolist(), ik.tolist()))
        want = {(a, b) for a in range(4) for b in range(4) if b <= a}
        assert cells == want
        assert len(iq) == len(want)  # no duplicates


def test_schedule_sfc_vmem_reuse():
    """SFC schedules reuse VMEM-resident q/kv blocks far better than
    row-major — the paper's LRU model applied to the kernel's block
    fetch stream (hilbert additionally has unit-step traversal)."""
    from repro.core.cache_model import simulate_lru

    def misses(kind, n=16, cap=12):
        iq, ik = build_schedule(n, n, causal=False, block_q=1, block_k=1,
                                kind=kind)
        stream, ids = [], {}
        for a, b in zip(iq.tolist(), ik.tolist()):
            for key in (("q", a), ("k", b), ("v", b)):
                stream.append(ids.setdefault(key, len(ids)))
        return simulate_lru(np.asarray(stream), cap)

    m_rm = misses("row_major")
    m_mo = misses("morton")
    m_hi = misses("hilbert")
    assert m_mo < m_rm / 2
    assert m_hi < m_rm / 2
    # hilbert: unit steps in the block grid
    iq_h, ik_h = build_schedule(8, 8, causal=False, block_q=1, block_k=1,
                                kind="hilbert")
    steps = np.abs(np.diff(iq_h)) + np.abs(np.diff(ik_h))
    assert steps.max() == 1
