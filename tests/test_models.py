"""Model substrate correctness: decode==forward, SSD vs recurrence, MoE
dispatch exactness, layout roundtrips, data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HILBERT, MORTON, OrderingSpec
from repro.core.layout import blockize, blockize_with_halo, unblockize
from repro.data import TokenPipeline
from repro.models import build_model
from repro.models.config import (HybridConfig, MLAConfig, ModelConfig,
                                 MoEConfig, SSMConfig)
from repro.models.mamba2 import ssd_chunked, ssd_decode_step
from repro.models.moe import moe_ffn

rng = np.random.default_rng(7)


def _tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                activation_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


_CONSISTENCY = {
    "dense": _tiny("dense"),
    "gemma-pattern": _tiny("dense", sliding_window=8, global_every=2,
                           n_kv_heads=1),
    "mla-moe": _tiny("moe", n_kv_heads=4,
                     mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16,
                                   qk_rope_dim=8, v_dim=16),
                     moe=MoEConfig(n_routed=8, n_shared=2, top_k=2,
                                   d_ff_expert=32, first_k_dense=1,
                                   capacity_factor=4.0)),
    "ssm": _tiny("ssm", n_heads=1, n_kv_heads=1, d_ff=0,
                 ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=4)),
    "hybrid": _tiny("hybrid", n_heads=4, n_kv_heads=4, d_ff=0,
                    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=4),
                    hybrid=HybridConfig(period=2, shared_d_ff=128,
                                        shared_n_heads=4,
                                        shared_n_kv_heads=4)),
}


@pytest.mark.parametrize("name", list(_CONSISTENCY))
def test_decode_matches_forward(name):
    """Step-by-step decode reproduces teacher-forced forward logits —
    validates every cache type (KV, MLA latent, SSM state, conv, shared)."""
    cfg = _CONSISTENCY[name]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    batch = {"tokens": toks, "labels": toks}
    logits_full, _ = m.forward(params, batch)
    cache = m.init_cache(B, S, jnp.float32)
    dec = jax.jit(m.decode)
    errs = []
    for t in range(S):
        db = {"tokens": toks[:, t:t + 1], "cur": jnp.asarray(t, jnp.int32)}
        lg, cache = dec(params, cache, db)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, t]))))
    assert max(errs) < 2e-2, (name, max(errs))


def test_ssd_chunked_equals_recurrence():
    """Chunked SSD (dual form) == naive per-step recurrence."""
    B, T, H, P, N, G = 2, 32, 4, 8, 16, 1
    x = jnp.asarray(rng.normal(size=(B, T, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, T, H)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, T, G, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, T, G, N)).astype(np.float32))
    for chunk in (4, 8, 16, 32):
        y = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        h = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(T):
            yt, h = ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
            ys.append(yt)
        y_ref = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


def test_moe_dispatch_matches_dense_eval():
    """Sort-based capacity dispatch == per-token dense evaluation when
    capacity is unbounded."""
    cfg = _tiny("moe", moe=MoEConfig(n_routed=8, n_shared=1, top_k=2,
                                     d_ff_expert=32, first_k_dense=0,
                                     capacity_factor=100.0))
    D, E, Fe = cfg.d_model, 8, 32
    p = {
        "router": jnp.asarray(rng.normal(size=(D, E)).astype(np.float32)),
        "w1": jnp.asarray(rng.normal(size=(E, D, Fe)).astype(np.float32)) * 0.1,
        "w3": jnp.asarray(rng.normal(size=(E, D, Fe)).astype(np.float32)) * 0.1,
        "w2": jnp.asarray(rng.normal(size=(E, Fe, D)).astype(np.float32)) * 0.1,
        "shared_gate": jnp.zeros((D, Fe)),
        "shared_up": jnp.zeros((D, Fe)),
        "shared_down": jnp.zeros((Fe, D)),
    }
    x = jnp.asarray(rng.normal(size=(2, 8, D)).astype(np.float32))
    out, aux = moe_ffn(p, x, cfg)

    # dense reference: evaluate every expert for every token, weight by gate
    xt = x.reshape(-1, D)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", xt, p["w1"])
    u = jnp.einsum("td,edf->tef", xt, p["w3"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("tef,efd->ted", h, p["w2"])
    ref = jnp.zeros_like(xt)
    for kk in range(2):
        ref = ref + gate[:, kk:kk + 1] * jnp.take_along_axis(
            ye, ids[:, kk][:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, D)), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops():
    """With capacity factor << 1 tokens are dropped, not corrupted."""
    cfg = _tiny("moe", moe=MoEConfig(n_routed=4, n_shared=1, top_k=1,
                                     d_ff_expert=16, first_k_dense=0,
                                     capacity_factor=0.25))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32))
    logits, _ = m.forward(params, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("kind", ["morton", "hilbert"])
def test_blockize_roundtrip(kind):
    M, T = 16, 4
    x = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    blocks = blockize(x, T, kind)
    back = unblockize(blocks, M, kind)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("kind", ["morton", "hilbert"])
def test_blockize_with_halo_periodic(kind):
    M, T, g = 8, 4, 1
    x = jnp.asarray(rng.normal(size=(M, M, M)).astype(np.float32))
    blocks = blockize_with_halo(x, T, g, kind, periodic=True)
    xp = np.pad(np.asarray(x), g, mode="wrap")
    from repro.core.layout import block_order
    bo = block_order(kind, M // T)
    for b in range(blocks.shape[0]):
        bk, bi, bj = bo[b] * T
        want = xp[bk:bk + T + 2 * g, bi:bi + T + 2 * g, bj:bj + T + 2 * g]
        np.testing.assert_array_equal(np.asarray(blocks[b]), want)


def test_pipeline_deterministic_and_seekable():
    p = TokenPipeline(vocab=100, batch=2, seq=32, seed=5)
    b3a = p.batch_at(3)
    b3b = p.batch_at(3)
    np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
    b4 = p.batch_at(4)
    assert not np.array_equal(b3a["tokens"], b4["tokens"])
    assert (b3a["tokens"] < 100).all() and (b3a["tokens"] >= 0).all()
    # labels are next-token shifted view of the same stream
    it = iter(p)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], p.batch_at(0)["tokens"])


def test_loss_decreases_on_tiny_model():
    from repro.train import OptConfig, TrainConfig, make_train_step
    from repro.train.optimizer import init_opt_state
    cfg = _tiny("dense", n_layers=2, vocab=64)
    m = build_model(cfg)
    pipe = TokenPipeline(vocab=64, batch=8, seq=32, seed=1)
    params = m.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(m, TrainConfig(
        opt=OptConfig(lr=1e-3, warmup_steps=5, total_steps=60))))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1


def test_microbatch_equals_full_batch_grads():
    """Grad accumulation is loss-equivalent to the unsplit batch."""
    from repro.train import OptConfig, TrainConfig, make_train_step
    from repro.train.optimizer import init_opt_state
    cfg = _tiny("dense", n_layers=2, vocab=64)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             TokenPipeline(vocab=64, batch=8, seq=16, seed=2).batch_at(0).items()}
    outs = []
    for micro in (1, 2, 4):
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(m, TrainConfig(
            opt=OptConfig(warmup_steps=1, total_steps=10),
            microbatches=micro)))
        p2, _, metrics = step(params, opt, batch)
        outs.append((float(metrics["loss"]), p2))
    for loss, p2 in outs[1:]:
        assert abs(loss - outs[0][0]) < 1e-4
        for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
