"""Deterministic stand-in for the tiny hypothesis subset the suite uses.

This container doesn't ship ``hypothesis`` and the rules forbid
installing it; rather than skip whole modules (losing the plain tests
that share them), property tests fall back to seeded random sampling:
``@given`` draws ``max_examples`` inputs from a fixed-seed generator, so
runs are reproducible, just not shrinking/adversarial. CI environments
with real hypothesis installed use it automatically (see the importing
modules' try/except).

Covers only what the suite needs: ``given``, ``settings``,
``st.integers``, ``st.lists``, ``st.data``.
"""

from __future__ import annotations

import numpy as np

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class integers:
    def __new__(cls, min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))


class lists:
    def __new__(cls, elements, *, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


class data:
    def __new__(cls):
        s = _Strategy(None)
        s._is_data = True
        return s


def given(*strategies):
    def deco(fn):
        # plain zero-arg wrapper: pytest must NOT see the drawn parameters
        # (functools.wraps would re-expose them as fixtures via __wrapped__)
        def wrapper():
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
            seed = int.from_bytes(fn.__name__.encode(), "little") % (2 ** 32)
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = [(_DataObject(rng) if getattr(s, "_is_data", False)
                          else s.example(rng)) for s in strategies]
                fn(*drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(deadline=None, max_examples=_DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        # works in either decorator order relative to @given
        fn._max_examples = max_examples
        return fn
    return deco


class st:
    integers = integers
    lists = lists
    data = data
