"""Docs stay buildable: doctests run, links and §-references resolve.

Tier-1 wrapper around docs/check_docs.py (the CI ``docs`` job runs the
same checker as a script) — documentation examples are executable
contracts here, not prose.
"""

import importlib.util
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(REPO, "docs", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_tree_exists():
    expected = {"quickstart.md", "orderings.md", "pipelines.md",
                "benchmarks.md"}
    have = {f for f in os.listdir(os.path.join(REPO, "docs"))
            if f.endswith(".md")}
    assert expected <= have, have


def test_docs_links_resolve():
    assert _checker().check_links() == []


def test_design_section_refs_resolve():
    mod = _checker()
    sections = mod.design_sections()
    # the load-bearing sections the docstrings cite
    assert {"1", "2", "3", "4", "5", "6", "7", "8", "9"} <= sections
    assert mod.check_design_refs() == []


def test_docs_doctests_pass():
    mod = _checker()
    sys.path.insert(0, os.path.join(REPO, "src"))
    assert mod.check_doctests() == []
