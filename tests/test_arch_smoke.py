"""Per-assigned-architecture smoke tests: reduced config, one forward/train
step + one decode step on CPU; asserts output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.configs.registry import ShapeSpec, concrete_batch
from repro.models import build_model
from repro.train import OptConfig, TrainConfig, make_train_step
from repro.train.optimizer import init_opt_state

SMOKE_SHAPE = ShapeSpec("smoke", 32, 2, "train")


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, SMOKE_SHAPE)
    batch = {k: (v % cfg.vocab if v.dtype == jnp.int32 and v.ndim else v)
             for k, v in batch.items()}
    step = jax.jit(make_train_step(model, TrainConfig(
        opt=OptConfig(warmup_steps=1, total_steps=10))))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    assert _finite(new_params)
    # params actually moved
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    cache = model.init_cache(B, S, jnp.float32)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "cur": jnp.asarray(0, jnp.int32)}
    logits, new_cache = jax.jit(model.decode)(params, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert _finite(new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_shapes(arch):
    """FULL configs: param tree builds abstractly (no allocation) and the
    parameter count is in the arch's advertised ballpark."""
    cfg = get_config(arch)
    model = build_model(cfg)
    n = model.n_params()
    expect = {
        "smollm-360m": 0.41e9, "gemma3-1b": 1.3e9,
        "deepseek-coder-33b": 33.3e9, "phi4-mini-3.8b": 4.5e9,
        "deepseek-v2-lite-16b": 15.7e9, "deepseek-moe-16b": 16.4e9,
        "whisper-small": 0.34e9, "internvl2-76b": 70.6e9,
        "zamba2-1.2b": 1.2e9, "mamba2-2.7b": 2.8e9,
    }[arch]
    assert abs(n - expect) / expect < 0.1
    abstract = model.abstract()
    assert all(isinstance(x, jax.ShapeDtypeStruct)
               for x in jax.tree.leaves(abstract))
    specs = model.specs()
    assert (jax.tree.structure(specs, is_leaf=lambda x: not isinstance(x, dict))
            == jax.tree.structure(abstract,
                                  is_leaf=lambda x: not isinstance(x, dict)))
