"""Checkpoint/restart: bit-exactness, atomicity, async, elastic restore."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import TokenPipeline
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.train import (OptConfig, TrainConfig, Trainer, TrainerConfig)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128,
                  activation_dtype="float32")


@pytest.fixture()
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def test_save_restore_roundtrip(tmp_ckpt):
    tree = {"a": {"b": jnp.arange(10, dtype=jnp.float32)},
            "c": jnp.ones((3, 4), jnp.bfloat16)}
    ckpt.save(tmp_ckpt, 7, tree, meta={"step": 7, "note": "x"})
    got, meta = ckpt.restore(tmp_ckpt)
    assert meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(got["a"]["b"]),
                                  np.asarray(tree["a"]["b"]))
    assert got["c"].dtype == np.dtype(jnp.bfloat16)


def test_latest_step_and_atomicity(tmp_ckpt):
    tree = {"x": jnp.zeros(4)}
    ckpt.save(tmp_ckpt, 1, tree, meta={"step": 1})
    ckpt.save(tmp_ckpt, 5, tree, meta={"step": 5})
    # a torn (tmp) checkpoint must be invisible to restore
    os.makedirs(os.path.join(tmp_ckpt, ".tmp_step_00000009"))
    assert ckpt.latest_step(tmp_ckpt) == 5
    _, meta = ckpt.restore(tmp_ckpt)
    assert meta["step"] == 5


def test_async_save(tmp_ckpt):
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    ckpt.save_async(tmp_ckpt, 3, tree, meta={"step": 3})
    ckpt.wait()
    got, _ = ckpt.restore(tmp_ckpt, 3)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(1000))


def test_restart_bit_exact(tmp_ckpt):
    """Kill after 3 steps, resume, final params identical to an unbroken run."""
    model = build_model(CFG)
    pipe = TokenPipeline(vocab=128, batch=4, seq=16, seed=0)
    tcn = TrainConfig(opt=OptConfig(warmup_steps=2, total_steps=6))

    full = Trainer(model, pipe, TrainerConfig(
        total_steps=6, ckpt_every=3, ckpt_dir=tmp_ckpt + "_full", log_every=100,
        train=tcn))
    p_full, _, _ = full.run(resume=False)

    Trainer(model, pipe, TrainerConfig(
        total_steps=3, ckpt_every=3, ckpt_dir=tmp_ckpt, log_every=100,
        train=tcn)).run(resume=False)
    resumed = Trainer(model, pipe, TrainerConfig(
        total_steps=6, ckpt_every=3, ckpt_dir=tmp_ckpt, log_every=100,
        train=tcn))
    p_res, _, _ = resumed.run(resume=True)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_with_shardings(tmp_ckpt):
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(tmp_ckpt, 1, tree, meta={"step": 1})
    sh = {"w": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
    got, _ = ckpt.restore(tmp_ckpt, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


def test_crash_between_chunk_writes(tmp_ckpt, monkeypatch):
    """A writer killed between chunk files leaves only a half-written
    ``.tmp_step_*`` dir: restore never observes it and keeps serving the
    previous checkpoint."""
    ckpt.save(tmp_ckpt, 1, {"a": jnp.zeros(4), "b": jnp.ones(4)},
              meta={"step": 1})
    # force multi-chunk layout, then die (hard, not OSError — no retry,
    # no cleanup, exactly like SIGKILL) on the second chunk write
    monkeypatch.setattr(ckpt, "_MAX_CHUNK_BYTES", 8)
    real_savez = np.savez
    calls = {"n": 0}

    def dying_savez(f, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise KeyboardInterrupt("killed mid-save")
        return real_savez(f, **kw)

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        ckpt.save(tmp_ckpt, 5, {"a": jnp.arange(4.0), "b": jnp.arange(4.0)},
                  meta={"step": 5})
    assert os.path.isdir(os.path.join(tmp_ckpt, ".tmp_step_00000005"))
    assert ckpt.valid_steps(tmp_ckpt) == [1]
    _, meta = ckpt.restore(tmp_ckpt)
    assert meta["step"] == 1


def test_crash_between_fsync_and_rename(tmp_ckpt, monkeypatch):
    """A writer killed after fsync but before the atomic rename leaves a
    fully-written tmp dir — still invisible: the rename IS the commit."""
    ckpt.save(tmp_ckpt, 2, {"x": jnp.zeros(4)}, meta={"step": 2})
    real_rename = os.rename

    def dying_rename(src, dst):
        if ".tmp_step_" in str(src):
            raise KeyboardInterrupt("killed pre-commit")
        return real_rename(src, dst)

    monkeypatch.setattr(os, "rename", dying_rename)
    with pytest.raises(KeyboardInterrupt):
        ckpt.save(tmp_ckpt, 6, {"x": jnp.ones(4)}, meta={"step": 6})
    monkeypatch.setattr(os, "rename", real_rename)
    tmp = os.path.join(tmp_ckpt, ".tmp_step_00000006")
    assert os.path.isfile(os.path.join(tmp, "manifest.json"))  # fully written
    assert ckpt.latest_step(tmp_ckpt) == 2  # ...but never committed
    got, meta = ckpt.restore(tmp_ckpt)
    assert meta["step"] == 2
    np.testing.assert_array_equal(np.asarray(got["x"]), np.zeros(4))


def test_overwrite_same_step(tmp_ckpt):
    ckpt.save(tmp_ckpt, 2, {"x": jnp.zeros(2)}, meta={"step": 2, "v": 1})
    ckpt.save(tmp_ckpt, 2, {"x": jnp.ones(2)}, meta={"step": 2, "v": 2})
    got, meta = ckpt.restore(tmp_ckpt, 2)
    assert meta["v"] == 2
    np.testing.assert_array_equal(np.asarray(got["x"]), np.ones(2))
