"""Cache model (Alg. 1) + offset histograms: paper-quantitative checks."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    HILBERT, MORTON, ROW_MAJOR, cache_misses, offset_histogram,
    offset_summary, simulate_lru, surface_cache_misses,
)
from repro.core.surfaces import run_stats, surface_path_indices, surface_runs


def test_row_major_closed_form():
    """§3.1: row-major has exactly (2g+1)³ offsets, each with count (M-2g)³."""
    for M, g in [(16, 1), (16, 2), (32, 1)]:
        keys, cnts = offset_histogram(ROW_MAJOR, M, g)
        assert len(keys) == (2 * g + 1) ** 3
        assert (cnts == (M - 2 * g) ** 3).all()
        # offsets are exactly {dk·M² + di·M + dj}
        r = np.arange(-g, g + 1)
        want = sorted(int(a * M * M + b * M + c)
                      for a in r for b in r for c in r)
        assert keys.tolist() == want


def test_sfc_histograms_scatter_but_localise():
    """Figs 5-6: SFC orderings scatter offsets more widely, yet put a larger
    fraction of accesses within a cache line."""
    M, g = 32, 1
    rm = offset_summary(ROW_MAJOR, M, g)
    mo = offset_summary(MORTON, M, g)
    hi = offset_summary(HILBERT, M, g)
    assert mo.n_distinct > rm.n_distinct
    assert hi.n_distinct > rm.n_distinct
    assert mo.frac_within_line > rm.frac_within_line
    assert hi.frac_within_line > rm.frac_within_line


def test_histogram_total_counts():
    M, g = 16, 1
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        _, cnts = offset_histogram(spec, M, g)
        assert cnts.sum() == (M - 2 * g) ** 3 * (2 * g + 1) ** 3


@given(st.lists(st.integers(0, 9), min_size=1, max_size=200),
       st.integers(1, 12))
@settings(deadline=None)
def test_lru_invariants(seq, c):
    lines = np.asarray(seq)
    misses = simulate_lru(lines, c)
    distinct = len(set(seq))
    assert distinct <= misses <= len(seq)
    # infinite cache -> cold misses only
    assert simulate_lru(lines, 10**6) == distinct
    # capacity monotonicity
    assert simulate_lru(lines, c + 1) <= misses


def test_lru_eviction_order():
    # capacity 2, sequence 0 1 0 2 1: misses = 0,1,2 cold + 1 (evicted by 2)
    assert simulate_lru(np.array([0, 1, 0, 2, 1]), 2) == 4


def test_surface_misses_sr_pathology():
    """Figs 11/16: with row-major layout the slab-row faces miss ~b× more
    than the contiguous faces; SFC layouts are near-uniform across faces."""
    M, g, b, c = 32, 1, 8, 64
    rm = {f: surface_cache_misses(ROW_MAJOR, M, g, b, c, f)
          for f in ("k0", "i0", "j0")}
    assert rm["j0"] >= 4 * rm["k0"]  # sr face pathological
    for spec in (MORTON, HILBERT):
        s = {f: surface_cache_misses(spec, M, g, b, c, f)
             for f in ("k0", "i0", "j0")}
        vals = np.array(list(s.values()), float)
        assert vals.max() / vals.min() <= 1.5  # near-uniform
        assert vals.max() < rm["j0"]           # beats the rm pathology


def test_interior_cache_misses_sane():
    M, g, b, c = 16, 1, 8, 32
    n_interior = (M - 2 * g) ** 3
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        m = cache_misses(spec, M, g, b, c)
        assert m >= M ** 3 / b * 0.5       # at least ~cold misses
        assert m <= n_interior * (2 * g + 1) ** 3


def test_surface_run_stats():
    """§4: pack-list run lengths. Row-major: rc face is one run, sr face is
    all runs of 1 (stride M²). Hilbert improves the sr face even at element
    granularity; Morton matches rm there (j is its least-significant bit)
    but wins at cache-line granularity (test_surface_misses_sr_pathology)
    and is near-isotropic across faces — unlike row-major."""
    M, g = 32, 1
    rm_rc = run_stats(ROW_MAJOR, M, g, "k0")
    rm_sr = run_stats(ROW_MAJOR, M, g, "j0")
    assert rm_rc.n_runs == 1 and rm_rc.max_run == M * M
    assert rm_sr.n_runs == M * M and rm_sr.max_run == 1
    hi_sr = run_stats(HILBERT, M, g, "j0")
    assert hi_sr.n_runs < M * M and hi_sr.mean_run > 1.0
    # Morton: face-isotropy — worst/best face ratio far below row-major's
    mo = [run_stats(MORTON, M, g, f).n_runs
          for f in ("k0", "i0", "j0")]
    rm = [run_stats(ROW_MAJOR, M, g, f).n_runs
          for f in ("k0", "i0", "j0")]
    assert max(mo) / min(mo) < max(rm) / min(rm)


def test_surface_indices_cover_face():
    M, g = 16, 2
    for spec in (ROW_MAJOR, MORTON, HILBERT):
        for face in ("k0", "k1", "i0", "i1", "j0", "j1"):
            idx = surface_path_indices(spec, M, g, face)
            assert idx.size == g * M * M
            assert len(np.unique(idx)) == idx.size
            starts, lens = surface_runs(spec, M, g, face)
            assert lens.sum() == idx.size


@pytest.mark.parametrize("spec", [ROW_MAJOR, MORTON, HILBERT],
                         ids=lambda s: s.name)
def test_surface_variant_stencil_mode(spec):
    m = surface_cache_misses(spec, 16, 1, 8, 64, "k0", stencil=True)
    m0 = surface_cache_misses(spec, 16, 1, 8, 64, "k0", stencil=False)
    assert m >= m0  # stencil touches strictly more data
