"""Roofline machinery: loop-aware HLO cost model validated on known graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import HW, RooflineCell, collective_bytes
from repro.roofline.hlo_cost import analyze_hlo


def _compile(f, *abstract):
    return jax.jit(f).lower(*abstract).compile()


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    c = _compile(lambda x, y: (x @ y).sum(), a, b)
    hc = analyze_hlo(c.as_text())
    want = 2 * 256 * 512 * 1024
    assert abs(hc.flops - want) / want < 0.01


def test_scan_flops_scale_with_length():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    def run(L):
        w = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

        def f(w, x):
            def body(x, wl):
                return x @ wl, None
            x, _ = jax.lax.scan(body, x, w)
            return x.sum()

        c = _compile(f, w, x)
        return analyze_hlo(c.as_text())

    f4, f16 = run(4), run(16)
    assert abs(f16.flops / f4.flops - 4.0) < 0.05
    want4 = 4 * 2 * 4 * 64 * 64
    assert abs(f4.flops - want4) / want4 < 0.2
    # bytes also scale with trip count
    assert f16.bytes / f4.bytes > 3.0


def test_nested_scan():
    def f(w, x):
        def outer(x, wl):
            def inner(x, _):
                return x @ wl, None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        x, _ = jax.lax.scan(outer, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 32), jnp.float32)
    hc = analyze_hlo(_compile(f, w, x).as_text())
    want = 5 * 3 * 2 * 2 * 32 * 32
    assert abs(hc.flops - want) / want < 0.2


def test_stacked_weight_slice_not_overcounted():
    """dynamic-slice of scan-stacked weights must count slice bytes, not
    the full (L, …) buffer per iteration."""
    L, D = 64, 128

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    hc = analyze_hlo(_compile(f, w, x).as_text())
    full_per_iter = L * (L * D * D * 4)  # the overcount this guards against
    assert hc.bytes < full_per_iter / 4


def test_roofline_cell_terms():
    cell = RooflineCell(arch="a", shape="s", mesh="m", n_devices=256,
                        flops=197e12 * 0.010,       # 10 ms compute
                        bytes_accessed=819e9 * 0.002,  # 2 ms memory
                        coll_bytes={"all-reduce": 50e9 * 0.004},  # 4 ms
                        model_flops_global=197e12 * 256 * 0.005)
    assert abs(cell.t_compute - 0.010) < 1e-9
    assert abs(cell.t_memory - 0.002) < 1e-9
    assert abs(cell.t_collective - 0.004) < 1e-9
    assert cell.bottleneck == "compute"
    assert abs(cell.t_bound - 0.010) < 1e-9
    assert abs(cell.mfu_bound - 0.5) < 1e-6


def test_collective_bytes_parser():
    text = """
  %all-reduce.1 = f32[1024,256]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64,128]{1,0} all-gather(%y), dimensions={0}
  %done = f32[8]{0} all-gather-done(%h)
"""
    out = collective_bytes(text)
    assert out["all-reduce"] == 1024 * 256 * 4
    assert out["all-gather"] == 64 * 128 * 2


def test_hw_constants_per_assignment():
    assert HW["flops_bf16"] == 197e12
    assert HW["hbm_bw"] == 819e9
    assert HW["ici_link_bw"] == 50e9
