"""Property-based tests (hypothesis) for the SFC core invariants."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    HILBERT, MORTON, ROW_MAJOR, OrderingSpec, hilbert_decode3,
    hilbert_encode3, morton_decode3, morton_encode3, path_to_rmo, rmo_to_path,
)
from repro.core.hilbert import hilbert_decode, hilbert_encode
from repro.core.morton import (
    dilate2, dilate3, morton_decode3_level, morton_encode3_level, undilate2,
    undilate3,
)
from repro.core.orderings import path_index_2d


@given(st.lists(st.integers(0, 2**21 - 1), min_size=1, max_size=64))
def test_dilate3_roundtrip(xs):
    x = np.asarray(xs, dtype=np.uint64)
    assert (undilate3(dilate3(x)) == x).all()


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
def test_dilate2_roundtrip(xs):
    x = np.asarray(xs, dtype=np.uint64)
    assert (undilate2(dilate2(x)) == x).all()


@given(st.integers(0, 2**20 - 1), st.integers(0, 2**20 - 1),
       st.integers(0, 2**20 - 1))
def test_morton3_roundtrip(k, i, j):
    idx = morton_encode3(np.uint64(k), np.uint64(i), np.uint64(j))
    kk, ii, jj = morton_decode3(idx)
    assert (int(kk), int(ii), int(jj)) == (k, i, j)


@given(st.integers(2, 5), st.data())
def test_morton_level_roundtrip(m, data):
    M = 1 << m
    r = data.draw(st.integers(0, m))
    coords = data.draw(st.lists(st.integers(0, M - 1), min_size=3, max_size=3))
    k, i, j = (np.uint64(c) for c in coords)
    idx = morton_encode3_level(k, i, j, m, r)
    kk, ii, jj = morton_decode3_level(idx, m, r)
    assert (int(kk), int(ii), int(jj)) == tuple(coords)


@given(st.integers(2, 5))
@settings(deadline=None, max_examples=4)
def test_morton_level_bijective(m):
    M = 1 << m
    kk, ii, jj = np.meshgrid(*(np.arange(M, dtype=np.uint64),) * 3,
                             indexing="ij")
    for r in range(m + 1):
        idx = morton_encode3_level(kk.ravel(), ii.ravel(), jj.ravel(), m, r)
        assert len(np.unique(idx)) == M ** 3


@given(st.integers(2, 5), st.integers(2, 5), st.integers(2, 5),
       st.integers(2, 4))
def test_hilbert3_roundtrip(k, i, j, m):
    M = 1 << m
    k, i, j = k % M, i % M, j % M
    idx = hilbert_encode3(np.uint64(k), np.uint64(i), np.uint64(j), m)
    kk, ii, jj = hilbert_decode3(idx, m)
    assert (int(kk), int(ii), int(jj)) == (k, i, j)


@pytest.mark.parametrize("m", [2, 3, 4])
def test_hilbert3_unit_neighbour(m):
    """Consecutive Hilbert positions are grid neighbours (|Δ|₁ == 1) —
    the continuity property Morton lacks (paper footnote 1)."""
    M = 1 << m
    kk, ii, jj = np.meshgrid(*(np.arange(M, dtype=np.uint64),) * 3,
                             indexing="ij")
    h = hilbert_encode3(kk.ravel(), ii.ravel(), jj.ravel(), m)
    q = np.empty(M ** 3, np.int64)
    q[h.astype(np.int64)] = np.arange(M ** 3)
    coords = np.stack([kk.ravel(), ii.ravel(), jj.ravel()], 1).astype(np.int64)[q]
    steps = np.abs(np.diff(coords, axis=0)).sum(1)
    assert steps.max() == 1
    assert (coords[0] == 0).all()


@pytest.mark.parametrize("b", [2, 3, 4])
def test_hilbert2_unit_neighbour(b):
    n = 1 << b
    ii, jj = np.meshgrid(np.arange(n, dtype=np.uint64),
                         np.arange(n, dtype=np.uint64), indexing="ij")
    h = hilbert_encode([ii.ravel(), jj.ravel()], b)
    q = np.empty(n * n, np.int64)
    q[h.astype(np.int64)] = np.arange(n * n)
    c = np.stack([ii.ravel(), jj.ravel()], 1).astype(np.int64)[q]
    assert np.abs(np.diff(c, axis=0)).sum(1).max() == 1


_SPECS = [ROW_MAJOR, MORTON, HILBERT,
          OrderingSpec("column_major"),
          OrderingSpec("morton", level=1),
          OrderingSpec("morton", level=2),
          OrderingSpec("hybrid", tile=4, outer="hilbert", inner="row_major"),
          OrderingSpec("hybrid", tile=4, outer="morton", inner="hilbert"),
          OrderingSpec("hybrid", tile=2, outer="row_major", inner="morton")]


@pytest.mark.parametrize("spec", _SPECS, ids=lambda s: s.name)
@pytest.mark.parametrize("M", [8, 16])
def test_permutations_inverse(spec, M):
    p = rmo_to_path(spec, M)
    q = path_to_rmo(spec, M)
    n = M ** 3
    assert (np.sort(p) == np.arange(n)).all()
    assert (p[q] == np.arange(n)).all()
    assert (q[p] == np.arange(n)).all()


def test_row_major_is_identity():
    assert (rmo_to_path(ROW_MAJOR, 8) == np.arange(512)).all()


def test_morton_full_depth_first_block():
    """Fig. 1: full Morton visits the (0..1)³ block first, row-major inside."""
    q = path_to_rmo(MORTON, 4)
    M = 4
    first8 = q[:8]
    coords = np.stack([first8 // (M * M), (first8 // M) % M, first8 % M], 1)
    want = [(0, 0, 0), (0, 0, 1), (0, 1, 0), (0, 1, 1),
            (1, 0, 0), (1, 0, 1), (1, 1, 0), (1, 1, 1)]
    assert [tuple(c) for c in coords] == want


@pytest.mark.parametrize("kind", ["row_major", "morton", "hilbert"])
@pytest.mark.parametrize("n", [4, 8])
def test_path_index_2d_is_permutation(kind, n):
    seq = path_index_2d(kind, n)
    assert (np.sort(seq) == np.arange(n * n)).all()
