"""Communication-avoiding distributed pipeline (DESIGN.md §7) + satellites.

Three layers of coverage:

- pure-local tests (any device count): deep pack/scatter round-trips at
  h = S·g ∈ {1,2,3,4}, shell scatter completeness, extended neighbour
  tables, the exchange-aware bytes model and plan();
- a 1×1×1-mesh test (any device count): the full exchange+compute round
  with every ppermute a self-send — periodic wrap, checked against the
  global oracle in-process;
- the acceptance matrix on a ≥8-device mesh: DistributedPipeline with S
  substeps per exchange vs S sequential make_distributed_step steps,
  bit-identical, for all four orderings × {gol, jacobi} × S ∈ {1, 2, 4}.
  Runs in-process when the interpreter already has ≥8 devices (the
  multi-device CI job forces a host-platform mesh), else in a
  subprocess, so the shard_map paths are exercised in tier-1 everywhere.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (COLUMN_MAJOR, HILBERT, MORTON, ROW_MAJOR,
                        OrderingSpec, apply_ordering)
from repro.core.layout import store_spec
from repro.core.neighbors import (SELF_COL, extended_neighbor_table,
                                  neighbor_table, shell_block_count,
                                  shell_block_index)
from repro.core.surfaces import shell_slab_positions, shell_slab_shapes
from repro.kernels import ref as kref
from repro.kernels.ops import pack_surface
from repro.stencil import (DistributedPipeline, distributed_bytes_per_step,
                           exchange_bytes_per_step,
                           exchange_items_per_exchange, fused_vmem_bytes,
                           make_distributed_step, make_stencil_mesh,
                           resident_bytes_per_step, shard_state,
                           surface_slab_scatter, unshard_state,
                           VMEM_BUDGET_BYTES)
from repro.stencil.halo import exchange_shell, shard_substeps

rng = np.random.default_rng(7)

ORDERINGS = (ROW_MAJOR, COLUMN_MAJOR, MORTON, HILBERT)
FACE_SLICES = {
    "k0": lambda c, h: c[:h], "k1": lambda c, h: c[-h:],
    "i0": lambda c, h: c[:, :h, :], "i1": lambda c, h: c[:, -h:, :],
    "j0": lambda c, h: c[:, :, :h], "j1": lambda c, h: c[:, :, -h:],
}
FACE_SHAPES = {
    "k": lambda M, h: (h, M, M), "i": lambda M, h: (M, h, M),
    "j": lambda M, h: (M, M, h),
}


# --------------------------------------------- deep pack/scatter (satellite)
@pytest.mark.parametrize("spec", ORDERINGS, ids=lambda s: s.name)
@pytest.mark.parametrize("h", [1, 2, 3, 4])
def test_deep_pack_scatter_roundtrip(spec, h):
    """pack_surface + surface_slab_scatter at width h = S·g reconstruct
    the canonical face slice exactly, for every face and ordering."""
    M = 8
    cube = rng.normal(size=(M, M, M)).astype(np.float32)
    path = apply_ordering(jnp.asarray(cube), spec)
    for face, take in FACE_SLICES.items():
        buf = pack_surface(path, spec, M, h, face)
        pos = surface_slab_scatter(spec, M, h, face)
        shape = FACE_SHAPES[face[0]](M, h)
        slab = np.zeros(h * M * M, np.float32)
        slab[pos] = np.asarray(buf)
        np.testing.assert_array_equal(slab.reshape(shape),
                                      take(cube, h), err_msg=face)


@pytest.mark.parametrize("kind", ["morton", "hilbert", "row_major"])
def test_deep_pack_from_block_store(kind):
    """The block store is path-ordered state under store_spec(kind, T):
    deep faces pack straight from the ravelled store."""
    from repro.core import blockize

    M, T, h = 16, 8, 4
    cube = rng.normal(size=(M, M, M)).astype(np.float32)
    store = blockize(jnp.asarray(cube), T, kind=kind)
    hspec = store_spec(kind, T)
    np.testing.assert_array_equal(
        np.asarray(store).ravel(),
        np.asarray(apply_ordering(jnp.asarray(cube), hspec)))
    buf = pack_surface(store.reshape(-1), hspec, M, h, "k1")
    pos = surface_slab_scatter(hspec, M, h, "k1")
    slab = np.zeros(h * M * M, np.float32)
    slab[pos] = np.asarray(buf)
    np.testing.assert_array_equal(slab.reshape(h, M, M), cube[-h:])


def test_shell_slab_positions_cover_shell():
    """The six slab scatters tile the shell skin disjointly, and each
    position lands in the h-deep skin a fused-kernel piece spec reads."""
    nt, T, h = 2, 8, 3
    M = nt * T
    pos = shell_slab_positions(nt, T, h)
    assert pos.size == (M + 2 * h) ** 3 - M ** 3
    assert pos.size == sum(int(np.prod(s)) for s in shell_slab_shapes(M, h))
    assert np.unique(pos).size == pos.size
    assert pos.min() >= 0
    assert pos.max() < shell_block_count(nt) * T ** 3


def test_extended_neighbor_table_core_and_shell():
    """Core offsets match the clamped-free interior; boundary offsets
    address the appended shell blocks; SELF_COL is the row index."""
    from repro.core.layout import block_order
    from repro.core.neighbors import OFFSETS_FULL

    nt = 2
    nb = nt ** 3
    ext = extended_neighbor_table("morton", nt)
    per = neighbor_table("morton", nt, periodic=True)
    assert ext.shape == per.shape == (nb, 27)
    np.testing.assert_array_equal(ext[:, SELF_COL], np.arange(nb))
    # brute force: in-core offsets agree with the periodic table's
    # non-wrapping entries, out-of-core offsets address the right shell id
    bo = block_order("morton", nt)
    sid = shell_block_index(nt)
    for t in range(nb):
        for o, (a, b, c) in enumerate(OFFSETS_FULL):
            co = bo[t] + (a, b, c)
            if ((co >= 0) & (co < nt)).all():
                assert ext[t, o] == per[t, o], (t, o)
            else:
                assert ext[t, o] == nb + sid[tuple(co + 1)], (t, o)
    assert ext.max() < nb + shell_block_count(nt)
    # larger grid: interior block's full neighbourhood stays in-core
    ext4 = extended_neighbor_table("hilbert", 4)
    per4 = neighbor_table("hilbert", 4, periodic=True)
    interior = (ext4 < 64).all(axis=1)
    assert interior.sum() == 2 ** 3  # the 2³ interior blocks of a 4³ grid
    np.testing.assert_array_equal(ext4[interior], per4[interior])


# --------------------------------------- exchange on a 1×1×1 mesh (periodic)
def test_exchange_shell_self_wrap_matches_pad():
    """On a 1-device mesh every ppermute is a self-send, so the shell
    must equal the periodic wrap-pad of the local cube."""
    from repro.core import blockize

    M, T, h = 16, 8, 2
    mesh = make_stencil_mesh((1, 1, 1))
    cube = rng.normal(size=(M, M, M)).astype(np.float32)
    store = blockize(jnp.asarray(cube), T, kind="hilbert")
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    fn = shard_map(
        lambda st: exchange_shell(st.reshape(-1), "hilbert", M, T, h),
        mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    k_lo, k_hi, i_lo, i_hi, j_lo, j_hi = map(np.asarray, fn(store))
    xp = np.pad(cube, h, mode="wrap")
    e = M + 2 * h
    np.testing.assert_array_equal(k_lo, xp[:h, h:h + M, h:h + M])
    np.testing.assert_array_equal(k_hi, xp[e - h:, h:h + M, h:h + M])
    np.testing.assert_array_equal(i_lo, xp[:, :h, h:h + M])
    np.testing.assert_array_equal(i_hi, xp[:, e - h:, h:h + M])
    np.testing.assert_array_equal(j_lo, xp[:, :, :h])
    np.testing.assert_array_equal(j_hi, xp[:, :, e - h:])


@pytest.mark.parametrize("use_kernel", [False, True])
def test_shard_substeps_self_wrap_matches_oracle(use_kernel):
    """One deep round on a 1×1×1 mesh == S periodic oracle steps (gol)."""
    from repro.core import blockize, unblockize
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    M, T, g, S = 16, 8, 1, 4
    mesh = make_stencil_mesh((1, 1, 1))
    cube = (rng.random((M, M, M)) < 0.3).astype(np.float32)
    store = blockize(jnp.asarray(cube), T, kind="morton")
    fn = shard_map(
        lambda st: shard_substeps(st, kind="morton", M=M, g=g, S=S,
                                  use_kernel=use_kernel),
        mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    got = np.asarray(unblockize(fn(store), M, kind="morton"))
    want = jnp.asarray(cube)
    for _ in range(S):
        want = kref.gol3d_step_ref(want, g)
    np.testing.assert_array_equal(got, np.asarray(want))


# ------------------------------------------------- sharded-state round trip
def test_shard_unshard_roundtrip():
    GM = 16
    cube = rng.normal(size=(GM, GM, GM)).astype(np.float32)
    for spec in (HILBERT, ROW_MAJOR):
        st = shard_state(jnp.asarray(cube), spec, (2, 2, 2))
        assert st.shape == (2, 2, 2, 8 ** 3)
        back = unshard_state(st, spec, GM)
        np.testing.assert_array_equal(np.asarray(back), cube)


# ----------------------------------------------- bytes model + plan (accept)
def test_exchange_model_matches_slab_shapes():
    """The ICI model is exactly the six exchanged slab volumes — one
    accounting between the exchange code and the benchmark rows."""
    for M, g, S in [(16, 1, 1), (16, 1, 4), (64, 1, 4), (64, 2, 2)]:
        h = S * g
        slabs = sum(int(np.prod(s)) for s in shell_slab_shapes(M, h))
        assert exchange_items_per_exchange(M, g, S) == slabs
        assert exchange_bytes_per_step(M, g, S) == 4.0 * slabs / S


def test_distributed_bytes_acceptance():
    """Acceptance: at the PR-2 reference point (local M=64, T=8, g=1)
    total modelled bytes/step (HBM + exchange) at S=4 is strictly below
    S=1 — asserted from the shared helpers (same accounting as the
    stencil_update rows)."""
    lo = distributed_bytes_per_step(64, 8, 1, 8, S=4)
    hi = distributed_bytes_per_step(64, 8, 1, 8, S=1)
    assert lo < hi
    # decomposition: the HBM term is the resident fused model, the ICI
    # term the exchange model — nothing else
    assert lo == resident_bytes_per_step(64, 8, 1, 8, S=4) + \
        exchange_bytes_per_step(64, 1, 4)
    # deep exchanges move slightly MORE wire bytes (corner growth): the
    # win is HBM amortisation + fewer messages, not fewer halo bytes
    assert exchange_bytes_per_step(64, 1, 4) > exchange_bytes_per_step(64, 1, 1)


def test_distributed_plan_minimises_joint_cost():
    """plan() optimises HBM+ICI over the same (T, S) grid as the
    resident plan, never exceeding any enumerable candidate."""
    mesh = make_stencil_mesh((1, 1, 1))
    for M, g, lim in [(16, 1, VMEM_BUDGET_BYTES), (64, 1, 64 * 1024),
                      (64, 2, 256 * 1024)]:
        pipe = DistributedPipeline.plan(mesh, HILBERT, M, g=g,
                                        vmem_limit=lim)
        assert fused_vmem_bytes(pipe.T, g, pipe.S) <= lim
        best = pipe.bytes_per_step(10)
        T = 1
        while T <= M:
            if M % T == 0 and T % g == 0:
                S = 1
                while S <= 8:
                    h = S * g
                    if h <= T and T % h == 0 and \
                            fused_vmem_bytes(T, g, S) <= lim:
                        assert best <= distributed_bytes_per_step(
                            M, T, g, 10, S=S)
                    S *= 2
            T *= 2


def test_pipeline_rejects_bad_S():
    mesh = make_stencil_mesh((1, 1, 1))
    with pytest.raises(ValueError):
        DistributedPipeline(mesh=mesh, spec=MORTON, M=16, T=8, g=1, S=3)
    with pytest.raises(ValueError):
        DistributedPipeline(mesh=mesh, spec=MORTON, M=16, T=8, g=2, S=8)


# ------------------------------------------------- acceptance matrix (≥ 8 dev)
def _run_acceptance_matrix():
    """DistributedPipeline S-deep run == S sequential make_distributed_step
    steps, bit-identical, all four orderings × {gol, jacobi} × S ∈ {1,2,4}.

    Shared by the in-process ≥8-device test (multi-device CI job) and the
    tier-1 subprocess runner.
    """
    mesh = make_stencil_mesh((2, 2, 2))
    local_M, g, GM = 8, 1, 16
    r = np.random.default_rng(3)
    data = {
        "gol": (r.random((GM, GM, GM)) < 0.35).astype(np.float32),
        "jacobi": r.normal(size=(GM, GM, GM)).astype(np.float32),
    }
    for spec in ORDERINGS:
        for rule, gcube in data.items():
            st0 = shard_state(jnp.asarray(gcube), spec, (2, 2, 2))
            step = make_distributed_step(mesh, spec, local_M, g, rule=rule)
            for S in (1, 2, 4):
                pipe = DistributedPipeline(mesh=mesh, spec=spec, M=local_M,
                                           T=8, g=g, S=S, rule=rule)
                got = np.asarray(jax.block_until_ready(pipe.run(st0, S)))
                want = st0
                for _ in range(S):
                    want = step(want)
                want = np.asarray(jax.block_until_ready(want))
                assert np.array_equal(got, want), (spec.name, rule, S)
    # and the gol column against the global periodic oracle
    want = jnp.asarray(data["gol"])
    for _ in range(4):
        want = kref.gol3d_step_ref(want, g)
    pipe = DistributedPipeline(mesh=mesh, spec=HILBERT, M=local_M, g=g, S=4)
    got = np.asarray(pipe.run_cube(jnp.asarray(data["gol"]), 4))
    assert np.array_equal(got, np.asarray(want))
    return True


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs >=8 devices (multi-device CI job)")
def test_acceptance_matrix_inprocess():
    assert _run_acceptance_matrix()


_SUBPROC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, %r)
from test_distributed_pipeline import _run_acceptance_matrix
assert _run_acceptance_matrix()
print("MATRIX_OK")
"""


def test_acceptance_matrix_subprocess():
    """Tier-1 form of the acceptance matrix: forces 8 host devices in a
    subprocess (the main pytest process must keep seeing 1 device)."""
    if jax.device_count() >= 8:
        pytest.skip("in-process variant already covers this")
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(here, "..", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROC % here],
                       capture_output=True, text=True, env=env, timeout=1200)
    assert "MATRIX_OK" in r.stdout, r.stdout + r.stderr
