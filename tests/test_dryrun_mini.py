"""Dry-run machinery regression: lower+compile real cells on a small mesh.

Uses an 8-device (2,4)=(data,model) mesh in a subprocess (device count is
process-global) with reduced shapes — exercises sanitize_specs, sharded
train/prefill/decode step construction and the roofline analyzer on the
very code paths the 512-chip run uses.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke
from repro.configs.registry import ShapeSpec, input_specs
from repro.launch.dryrun import sanitize_specs, _batch_specs, _ns
from repro.models import build_model
from repro.roofline.analysis import analyze
from repro.serve import make_serve_step
from repro.train import TrainConfig, make_train_step

mesh = jax.make_mesh((2, 4), ("data", "model"))
arch = "%s"
cfg = get_smoke(arch)
cfg = dataclasses.replace(cfg, act_spec=(("data",), "model", None))
if cfg.family == "moe":
    cfg = dataclasses.replace(cfg, ep_axis="model")
model = build_model(cfg)

# ---- train cell
shape = ShapeSpec("mini_train", 64, 8, "train")
pa = model.abstract(jnp.float32)
ps = sanitize_specs(mesh, model.specs(), pa)
oa = {"m": pa, "v": pa, "step": jax.ShapeDtypeStruct((), jnp.int32)}
os_ = {"m": ps, "v": ps, "step": P()}
ba = input_specs(cfg, shape)
bs = _batch_specs(ba, ("data",))
step = make_train_step(model, TrainConfig())
j = jax.jit(step, in_shardings=(_ns(mesh, ps), _ns(mesh, os_), _ns(mesh, bs)),
            out_shardings=(_ns(mesh, ps), _ns(mesh, os_),
                           _ns(mesh, jax.tree.map(lambda _: P(),
                               {"loss": 0, "grad_norm": 0, "lr": 0}))))
with mesh:
    c = j.lower(pa, oa, ba).compile()
cell = analyze(arch, "mini_train", "mini", 8, c, 6.0 * model.n_params() * 512)
assert cell.flops > 0 and cell.bytes_accessed > 0
assert cell.bottleneck in ("compute", "memory", "collective")

# ---- decode cell
dshape = ShapeSpec("mini_decode", 64, 8, "decode")
cfg2 = dataclasses.replace(cfg, act_spec=None,
                           score_spec=(("data",), None, None, "model"))
model2 = build_model(cfg2)
pa2 = model2.abstract(jnp.bfloat16)
ps2 = sanitize_specs(mesh, model2.specs(), pa2)
ca = model2.abstract_cache(8, 64, jnp.bfloat16)
cs = sanitize_specs(mesh, model2.cache_specs(
    8, 64, extra_rules={"batch": ("data",), "seq": "model",
                        "kv_heads": None, "heads": None}), ca)
da = input_specs(cfg2, dshape)
ds = _batch_specs(da, ("data",))
sstep = make_serve_step(model2)
j2 = jax.jit(sstep, in_shardings=(_ns(mesh, ps2), _ns(mesh, cs), _ns(mesh, ds)),
             out_shardings=(NamedSharding(mesh, P(("data",))), _ns(mesh, cs)),
             donate_argnums=(1,))
with mesh:
    c2 = j2.lower(pa2, ca, da).compile()
print("MINI_DRYRUN_OK")
"""


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma3-1b",
                                  "deepseek-moe-16b", "mamba2-2.7b",
                                  "zamba2-1.2b"])
def test_mini_dryrun_compiles(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT % arch],
                       capture_output=True, text=True, env=env, timeout=900)
    assert "MINI_DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
