"""Fault tolerance end to end (DESIGN.md §10).

Four layers of coverage:

- hardened-checkpoint units: torn/manifest-less dirs tolerated,
  truncation and bit-flips detected by crc32 and quarantined with
  fallback to the previous valid step, bounded save retry;
- CheckpointedRun units (single device): chunked == unchunked,
  resume bit-identity after injected kills at arbitrary steps — with
  the resuming pipeline using a *different* ordering/T/S — physics
  validation on resume, runtime guards (NaN + rule invariants);
- the subprocess kill CLI: a real ``os._exit`` death mid-run, resumed
  by a second process, crc-identical to an uninterrupted third;
- the elastic reshard matrix on a ≥8-device mesh: kill on mesh A,
  resume on mesh B (different shape/ordering/T/S, including
  distributed -> resident and a non-cubic 4×2×1 global box),
  bit-identical to the uninterrupted run. In-process when the
  interpreter has ≥8 devices (multi-device CI job), else in a
  subprocess, mirroring test_distributed_pipeline.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.ckpt import CheckpointCorruptError
from repro.launch.faults import (FaultPlan, SimulatedCrash, bitflip_chunk,
                                 drop_manifest, initial_state,
                                 make_dangling_tmp, state_crc,
                                 truncate_chunk)
from repro.stencil import (CheckpointedRun, ResidentPipeline, RunHealthError,
                           checkpoint_bytes_per_interval,
                           checkpoint_traffic_fraction, health_check)
from repro.stencil.runner import boundary_to_json


@pytest.fixture()
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def _save_steps(d, steps):
    for s in steps:
        ckpt.save(d, s, {"state": np.full(8, float(s), np.float32)},
                  meta={"step": s})


# ------------------------------------------------- hardened checkpoint layer
def test_valid_steps_skips_tmp_and_manifestless(tmp_ckpt):
    _save_steps(tmp_ckpt, [2, 4])
    make_dangling_tmp(tmp_ckpt, 6)            # writer died pre-rename
    drop_manifest(tmp_ckpt, 4)                # torn checkpoint
    os.makedirs(os.path.join(tmp_ckpt, "step_bogus"))  # junk name
    assert ckpt.valid_steps(tmp_ckpt) == [2]
    assert ckpt.latest_step(tmp_ckpt) == 2
    _, meta = ckpt.restore(tmp_ckpt)
    assert meta["step"] == 2


def test_latest_step_empty_and_missing(tmp_ckpt):
    assert ckpt.latest_step(tmp_ckpt) is None
    os.makedirs(tmp_ckpt)
    make_dangling_tmp(tmp_ckpt, 1)
    assert ckpt.latest_step(tmp_ckpt) is None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_ckpt)


@pytest.mark.parametrize("corrupt", [truncate_chunk, bitflip_chunk],
                         ids=["truncate", "bitflip"])
def test_corrupt_chunk_falls_back_and_quarantines(tmp_ckpt, corrupt):
    """crc32/readability failures on the newest checkpoint fall back to
    the previous valid step and quarantine the corrupt dir."""
    _save_steps(tmp_ckpt, [3, 6])
    corrupt(tmp_ckpt, 6)
    got, meta = ckpt.restore(tmp_ckpt)
    assert meta["step"] == 3
    np.testing.assert_array_equal(got["state"], np.full(8, 3.0, np.float32))
    assert os.path.isdir(os.path.join(tmp_ckpt, ".corrupt_step_00000006"))
    assert ckpt.valid_steps(tmp_ckpt) == [3]  # quarantined dir is skipped


def test_corrupt_explicit_step_raises(tmp_ckpt):
    _save_steps(tmp_ckpt, [5])
    bitflip_chunk(tmp_ckpt, 5)
    with pytest.raises(CheckpointCorruptError):
        ckpt.restore(tmp_ckpt, 5)
    # no fallback target left -> FileNotFoundError carrying the cause
    with pytest.raises(FileNotFoundError, match="crc|chunk"):
        ckpt.restore(tmp_ckpt)


def test_restore_without_verify_skips_crc(tmp_ckpt):
    _save_steps(tmp_ckpt, [1])
    bitflip_chunk(tmp_ckpt, 1)
    try:  # bitflip may hit zip structure (unreadable either way) or payload
        got, meta = ckpt.restore(tmp_ckpt, 1, verify=False)
        assert meta["step"] == 1
    except CheckpointCorruptError as e:
        assert "unreadable" in str(e)


def test_save_retries_transient_io_error(tmp_ckpt, monkeypatch):
    """One transient OSError during the write is absorbed by the retry;
    the checkpoint lands intact."""
    real_rename = os.rename
    fails = {"n": 1}

    def flaky_rename(src, dst):
        if fails["n"] and ".tmp_step_" in str(src):
            fails["n"] -= 1
            raise OSError("transient")
        return real_rename(src, dst)

    monkeypatch.setattr(os, "rename", flaky_rename)
    ckpt.save(tmp_ckpt, 9, {"x": np.arange(4)}, meta={"step": 9},
              retries=2, backoff=0.0)
    assert ckpt.latest_step(tmp_ckpt) == 9
    with pytest.raises(OSError):
        fails["n"] = 10  # fails every attempt -> exhausts the budget
        ckpt.save(tmp_ckpt, 10, {"x": np.arange(4)}, retries=1, backoff=0.0)


# ------------------------------------------------- checkpointed run (1 device)
M = 8


def _resident(rule="gol", **kw):
    d = dict(M=M, T=4, S=1, rule=rule, kind="morton")
    d.update(kw)
    return ResidentPipeline(**d)


def _ref(pipe, state0, n):
    return np.asarray(pipe.run(jnp.asarray(state0), n))


@pytest.mark.parametrize("rule,interval", [("gol", 3), ("jacobi", 4),
                                           ("wave", 5)])
def test_checkpointed_run_equals_plain(tmp_ckpt, rule, interval):
    """Chunked run == one-shot pipeline run, bit-identical, including
    intervals that do not divide n_steps and multi-field (C=2) state."""
    pipe = _resident(rule)
    state0 = initial_state(rule, M, seed=1)
    ref = _ref(pipe, state0, 10)
    out = CheckpointedRun(pipe, tmp_ckpt, interval=interval).run(state0, 10)
    np.testing.assert_array_equal(out, ref)
    # the final step is always checkpointed
    assert ckpt.latest_step(tmp_ckpt) == 10


@pytest.mark.parametrize("kill_at", [1, 5, 8])
@pytest.mark.parametrize("rule,resume_kw", [
    ("gol", dict(T=8, S=2, kind="hilbert")),
    ("jacobi", dict(T=8, kind="hilbert")),
], ids=["gol", "jacobi"])
def test_resume_bit_identity_after_kill(tmp_ckpt, rule, resume_kw, kill_at):
    """Kill at any step (boundary or not); resume with a *different*
    ordering and block edge (plus fused depth for the discrete rule);
    final state bit-identical to the uninterrupted run.

    The jacobi resume keeps S: on the jnp-oracle path XLA refuses
    FMA-determinism across different launch structures (ulp-level), so
    S-changed resume of averaging rules is a kernel-path guarantee —
    covered by test_resume_changed_S_kernel_path."""
    state0 = initial_state(rule, M, seed=2)
    ref = _ref(_resident(rule), state0, 10)
    plan = FaultPlan(kill_at_step=kill_at, kill_mode="raise")
    with pytest.raises(SimulatedCrash):
        CheckpointedRun(_resident(rule), tmp_ckpt, interval=4,
                        hooks=plan.hooks()).run(state0, 10)
    assert ckpt.latest_step(tmp_ckpt) <= kill_at  # kill precedes its ckpt
    resumed = CheckpointedRun(_resident(rule, **resume_kw),
                              tmp_ckpt, interval=4).run(state0, 10)
    np.testing.assert_array_equal(resumed, ref)


def test_resume_changed_S_kernel_path(tmp_ckpt):
    """On the Pallas kernel path an S-changed resume of an averaging
    rule is bit-identical too (the kernel fixes the substep arithmetic
    regardless of launch structure — test_fused_stencil discipline)."""
    state0 = initial_state("jacobi", M, seed=2)
    pipe = _resident("jacobi", use_kernel=True)
    ref = _ref(pipe, state0, 8)
    with pytest.raises(SimulatedCrash):
        CheckpointedRun(pipe, tmp_ckpt, interval=4,
                        hooks=FaultPlan(kill_at_step=6,
                                        kill_mode="raise").hooks()
                        ).run(state0, 8)
    resumed = CheckpointedRun(
        _resident("jacobi", T=8, S=2, kind="hilbert", use_kernel=True),
        tmp_ckpt, interval=4).run(state0, 8)
    np.testing.assert_array_equal(resumed, ref)


def test_resume_bit_identity_wave_and_clamped(tmp_ckpt):
    """Multi-field (C=2) state and a clamped boundary contract survive
    kill/resume with a changed ordering identically."""
    for rule, bc in [("wave", "periodic"), ("gol", "neumann0")]:
        d = os.path.join(tmp_ckpt, rule)
        pipe = _resident(rule, bc=bc)
        state0 = initial_state(rule, M, seed=3)
        ref = _ref(pipe, state0, 9)
        with pytest.raises(SimulatedCrash):
            CheckpointedRun(pipe, d, interval=4,
                            hooks=FaultPlan(kill_at_step=6,
                                            kill_mode="raise").hooks()
                            ).run(state0, 9)
        resumed = CheckpointedRun(_resident(rule, kind="hilbert", bc=bc),
                                  d, interval=4).run(state0, 9)
        np.testing.assert_array_equal(resumed, ref)


def test_resume_validates_physics(tmp_ckpt):
    """Layout may change on resume; physics may not — rule, boundary
    contract and shape mismatches are refused with a clear error."""
    state0 = initial_state("gol", M, seed=4)
    CheckpointedRun(_resident("gol"), tmp_ckpt, interval=4).run(state0, 4)
    with pytest.raises(ValueError, match="rule"):
        CheckpointedRun(_resident("jacobi"), tmp_ckpt).run(
            initial_state("jacobi", M), 8)
    with pytest.raises(ValueError, match="bc"):
        CheckpointedRun(_resident("gol", bc="dirichlet"), tmp_ckpt).run(
            state0, 8)
    with pytest.raises(ValueError, match="shape"):
        CheckpointedRun(ResidentPipeline(M=16, T=4, rule="gol"),
                        tmp_ckpt).run(initial_state("gol", 16), 8)
    # beyond-target checkpoint is an error, not a silent no-op
    with pytest.raises(ValueError, match="beyond"):
        CheckpointedRun(_resident("gol"), tmp_ckpt).run(state0, 2)


def test_boundary_contract_roundtrips_to_json():
    from repro.core.boundary import as_boundary, mixed

    assert boundary_to_json("periodic") == boundary_to_json(
        as_boundary("periodic"))
    j = boundary_to_json(mixed(k="dirichlet", i="periodic", j="neumann0"))
    assert j["kind"] == "mixed" and len(j["axes"]) == 3
    assert j["axes"][0]["kind"] == "dirichlet"


# ------------------------------------------------------------ runtime guards
def test_guard_nan_at_boundary(tmp_ckpt):
    """NaN injected at a checkpoint boundary trips the guard *at* that
    boundary — the poison is never checkpointed."""
    state0 = initial_state("gol", M, seed=5)
    with pytest.raises(RunHealthError) as ei:
        CheckpointedRun(_resident("gol"), tmp_ckpt, interval=4,
                        hooks=FaultPlan(poison_at_step=8).hooks()
                        ).run(state0, 10)
    assert ei.value.step == 8 and ei.value.last_good_step == 4
    assert "NaN" in ei.value.reason
    assert ckpt.latest_step(tmp_ckpt) == 4  # poisoned state not persisted


def test_guard_nan_propagates_to_next_boundary(tmp_ckpt):
    """jacobi propagates NaN; poison mid-interval is caught at the next
    checkpoint boundary with the previous interval still good."""
    state0 = initial_state("jacobi", M, seed=5)
    with pytest.raises(RunHealthError) as ei:
        CheckpointedRun(_resident("jacobi"), tmp_ckpt, interval=4,
                        hooks=FaultPlan(poison_at_step=5).hooks()
                        ).run(state0, 10)
    assert ei.value.step == 8 and ei.value.last_good_step == 4


def test_guard_rule_invariants(tmp_ckpt):
    """Finite-but-wrong states trip the per-rule invariants: gol must be
    exactly {0,1}, jacobi must respect its initial range (max principle)."""
    with pytest.raises(RunHealthError, match="0, 1"):
        CheckpointedRun(_resident("gol"), os.path.join(tmp_ckpt, "g"),
                        interval=4,
                        hooks=FaultPlan(poison_at_step=4,
                                        poison_value=0.5).hooks()
                        ).run(initial_state("gol", M, seed=6), 8)
    with pytest.raises(RunHealthError, match="maximum-principle"):
        CheckpointedRun(_resident("jacobi"), os.path.join(tmp_ckpt, "j"),
                        interval=4,
                        hooks=FaultPlan(poison_at_step=4,
                                        poison_value=1e6).hooks()
                        ).run(initial_state("jacobi", M, seed=6), 8)


def test_health_check_function():
    ok = np.zeros((4, 4, 4), np.float32)
    assert health_check("gol", ok) is None
    assert health_check("jacobi", ok, bounds=[-1.0, 1.0]) is None
    assert "NaN" in health_check("wave", np.full((2, 4), np.nan))
    assert "0, 1" in health_check("gol", ok + 0.25)
    assert "range" in health_check("jacobi", ok + 5.0, bounds=[-1.0, 1.0])
    assert health_check("jacobi", ok + 5.0, bounds=None) is None


def test_resume_falls_back_past_corrupt_checkpoint(tmp_ckpt):
    """Corrupting the newest checkpoint after a completed run: resume
    quarantines it, restores the previous valid step, re-runs the lost
    interval, and still reproduces the uninterrupted result bit-exactly."""
    pipe = _resident("jacobi")
    state0 = initial_state("jacobi", M, seed=7)
    ref = _ref(pipe, state0, 8)
    out = CheckpointedRun(pipe, tmp_ckpt, interval=2).run(state0, 8)
    np.testing.assert_array_equal(out, ref)
    truncate_chunk(tmp_ckpt, 8)
    resumed = CheckpointedRun(pipe, tmp_ckpt, interval=2).run(state0, 8)
    np.testing.assert_array_equal(resumed, ref)
    assert os.path.isdir(os.path.join(tmp_ckpt, ".corrupt_step_00000008"))
    assert ckpt.latest_step(tmp_ckpt) == 8  # re-written after the re-run


def test_keep_prunes_old_checkpoints(tmp_ckpt):
    state0 = initial_state("gol", M, seed=8)
    CheckpointedRun(_resident("gol"), tmp_ckpt, interval=2, keep=2
                    ).run(state0, 8)
    assert ckpt.valid_steps(tmp_ckpt) == [6, 8]


# ------------------------------------------------- checkpoint-overhead model
def test_checkpoint_model():
    assert checkpoint_bytes_per_interval(32) == 32 ** 3 * 4
    assert checkpoint_bytes_per_interval((16, 8, 4), fields=2) == \
        2 * 16 * 8 * 4 * 4
    f16 = checkpoint_traffic_fraction(32, 8, 1, 16, S=4)
    f64 = checkpoint_traffic_fraction(32, 8, 1, 64, S=4)
    assert 0.0 < f64 < f16 < 1.0  # longer intervals amortise the snapshot


# ------------------------------------------------------- subprocess kill CLI
_CLI = [sys.executable, "-m", "repro.launch.faults", "--M", "8", "--T", "4",
        "--rule", "gol", "--steps", "12", "--interval", "4"]


def _cli_env():
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(here, "..", "src")
    return env


def test_subprocess_kill_and_resume(tmp_path):
    """A real process death (os._exit mid-run): exit code 17, no
    checkpoint at/after the kill step; a second process resumes with a
    different ordering/T/S and matches an uninterrupted run's crc."""
    env = _cli_env()
    d_kill, d_ref = str(tmp_path / "kill"), str(tmp_path / "ref")
    r = subprocess.run(_CLI + ["--kill-at", "6", "--ckpt-dir", d_kill],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 17, r.stdout + r.stderr
    assert ckpt.latest_step(d_kill) == 4
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.faults", "--M", "8", "--T", "8",
         "--S", "2", "--ordering", "hilbert", "--rule", "gol", "--steps",
         "12", "--interval", "4", "--ckpt-dir", d_kill],
        capture_output=True, text=True, env=env, timeout=600)
    assert "FAULTS_DONE step=12" in r2.stdout, r2.stdout + r2.stderr
    r3 = subprocess.run(_CLI + ["--ckpt-dir", d_ref], capture_output=True,
                        text=True, env=env, timeout=600)
    crc = [ln.split("crc=")[1] for ln in (r2.stdout + r3.stdout).splitlines()
           if "FAULTS_DONE" in ln]
    assert len(crc) == 2 and crc[0] == crc[1], (r2.stdout, r3.stdout)


# ------------------------------------------- elastic reshard matrix (≥ 8 dev)
def _run_elastic_reshard_matrix(tmp_root="/tmp/repro_reshard"):
    """Kill on mesh A, resume on mesh B — different mesh shape, ordering,
    T and S — bit-identical to the uninterrupted run. Covers 8 -> 1
    device cubic reshard, a non-cubic 4×2×1 global box, and
    distributed -> resident takeover.

    Shared by the in-process ≥8-device test (multi-device CI job) and
    the tier-1 subprocess runner.
    """
    import shutil

    from repro.core import HILBERT, MORTON
    from repro.stencil import DistributedPipeline, make_stencil_mesh

    shutil.rmtree(tmp_root, ignore_errors=True)
    steps, interval = 12, 4

    def kill_run(pipe, d, state0):
        with np.testing.assert_raises(SimulatedCrash):
            CheckpointedRun(pipe, d, interval=interval,
                            hooks=FaultPlan(kill_at_step=6,
                                            kill_mode="raise").hooks()
                            ).run(state0, steps)

    # -- cubic: 2×2×2 (8 devices) -> 1×1×1, hilbert/T8/S2 -> morton/T4/S1
    d = os.path.join(tmp_root, "cubic")
    state0 = initial_state("gol", 16, seed=0)
    ref = np.asarray(DistributedPipeline(
        mesh=make_stencil_mesh((2, 2, 2)), spec=HILBERT, M=8, T=8, S=2
    ).run_cube(jnp.asarray(state0), steps))
    kill_run(DistributedPipeline(mesh=make_stencil_mesh((2, 2, 2)),
                                 spec=HILBERT, M=8, T=8, S=2), d, state0)
    out = CheckpointedRun(
        DistributedPipeline(mesh=make_stencil_mesh((1, 1, 1)), spec=MORTON,
                            M=16, T=4, S=1), d, interval=interval
    ).run(state0, steps)
    assert np.array_equal(out, ref), "cubic reshard diverged"

    # -- non-cubic global box: 4×2×1 over (32,16,8), morton/T8 ->
    #    hilbert/T4 (same S: oracle-path jacobi keeps launch structure)
    d = os.path.join(tmp_root, "noncubic")
    state0 = initial_state("jacobi", (32, 16, 8), seed=1)
    mesh421 = make_stencil_mesh((4, 2, 1))
    ref = np.asarray(DistributedPipeline(
        mesh=mesh421, spec=MORTON, M=8, T=8, S=1, rule="jacobi"
    ).run_cube(jnp.asarray(state0), steps))
    kill_run(DistributedPipeline(mesh=mesh421, spec=MORTON, M=8, T=8, S=1,
                                 rule="jacobi"), d, state0)
    out = CheckpointedRun(
        DistributedPipeline(mesh=mesh421, spec=HILBERT, M=8, T=4, S=1,
                            rule="jacobi"),
        d, interval=interval).run(state0, steps)
    assert np.array_equal(out, ref), "non-cubic reshard diverged"

    # -- distributed -> resident takeover (mesh lost entirely)
    d = os.path.join(tmp_root, "takeover")
    state0 = initial_state("gol", 16, seed=2)
    ref2 = np.asarray(ResidentPipeline(M=16, T=8, S=1, kind="hilbert"
                                       ).run(jnp.asarray(state0), steps))
    kill_run(DistributedPipeline(mesh=make_stencil_mesh((2, 2, 2)),
                                 spec=HILBERT, M=8, T=8, S=2), d, state0)
    out = CheckpointedRun(ResidentPipeline(M=16, T=8, S=1, kind="hilbert"),
                          d, interval=interval).run(state0, steps)
    assert np.array_equal(out, ref2), "distributed->resident diverged"
    shutil.rmtree(tmp_root, ignore_errors=True)
    return True


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs >=8 devices (multi-device CI job)")
def test_elastic_reshard_matrix_inprocess():
    assert _run_elastic_reshard_matrix()


_SUBPROC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, %r)
from test_resilience import _run_elastic_reshard_matrix
assert _run_elastic_reshard_matrix()
print("RESHARD_OK")
"""


def test_elastic_reshard_matrix_subprocess():
    """Tier-1 form of the reshard matrix: forces 8 host devices in a
    subprocess (the main pytest process must keep seeing 1 device)."""
    if jax.device_count() >= 8:
        pytest.skip("in-process variant already covers this")
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(here, "..", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROC % here],
                       capture_output=True, text=True, env=env, timeout=1200)
    assert "RESHARD_OK" in r.stdout, r.stdout + r.stderr
