"""ROI-query service: range decomposition properties, extraction
bit-identity against the dense cube, and the full serving fault matrix
(DESIGN.md §11).

Three layers, matching serve/roi.py and serve/service.py:

1. **Decomposition properties** (hypothesis): roi_to_ranges is exactly
   the intersecting block set (nothing missing, nothing extra), sorted,
   disjoint, minimal — and on aligned power-of-two ROIs hilbert needs
   at most (cubes: exactly 1 vs e²) as many ranges as row-major.
2. **Extraction exactness**: extract_roi over a ResidentPipeline's block
   store is bit-identical to slicing the unblockized cube, across
   ordering × boundary × channel count.
3. **Fault matrix**: every injected serving fault (failed fetch,
   bit-flipped payload, cache poison, deadline pressure, overload)
   surfaces as a typed QueryResult — recovered, degraded with an exact
   ``missing_ranges`` manifest, rejected, or error. Never a hang, never
   a silently wrong payload.

Plus the thread-safety satellites (layout.device_constant and the ops
row-plan LRU hammered from a pool) and the benchmark-model consistency
row the CI diff gate pins.
"""

import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.boundary import mixed
from repro.core.orderings import block_index_3d
from repro.launch.faults import ServeFaultPlan, initial_state
from repro.serve import (QUERY_STATUSES, ROI, FetchError, QueryResult,
                         StencilQueryService, StoreLayout, extract_roi,
                         merge_blocks_to_ranges, ranges_to_blocks, roi_model,
                         roi_to_ranges)

KINDS = ("row_major", "column_major", "morton", "hilbert")
MS = (8, 16, 32)


# ---------------------------------------------------------------------------
# 1. roi_to_ranges decomposition properties
# ---------------------------------------------------------------------------

def _brute_blocks(layout: StoreLayout, roi: ROI) -> set:
    """Independent oracle: curve indices of every block whose T³ extent
    intersects the ROI, by scanning the whole block grid."""
    T, nt = layout.T, layout.nt
    out = set()
    for bk in range(nt):
        for bi in range(nt):
            for bj in range(nt):
                b = (bk, bi, bj)
                if all(c * T < h and (c + 1) * T > l
                       for c, l, h in zip(b, roi.lo, roi.hi)):
                    out.add(int(block_index_3d(layout.kind, bk, bi, bj, nt)))
    return out


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_roi_to_ranges_exact_sorted_disjoint_minimal(data):
    """Union of ranges == intersecting block set; ranges are sorted,
    pairwise disjoint and non-adjacent (minimal), across all orderings
    and M ∈ {8, 16, 32}."""
    M = MS[data.draw(st.integers(0, len(MS) - 1))]
    kind = KINDS[data.draw(st.integers(0, len(KINDS) - 1))]
    lo = tuple(data.draw(st.integers(0, M - 1)) for _ in range(3))
    hi = tuple(data.draw(st.integers(l + 1, M)) for l in lo)
    layout = StoreLayout(M=M, T=4, kind=kind)
    roi = ROI(lo, hi)

    ranges = roi_to_ranges(layout, roi)
    assert all(a < b for a, b in ranges)
    for (_, b0), (a1, _) in zip(ranges, ranges[1:]):
        assert b0 < a1  # sorted + disjoint + non-adjacent == minimal
    assert set(ranges_to_blocks(ranges).tolist()) == _brute_blocks(layout, roi)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_aligned_pow2_cube_is_one_hierarchical_range(data):
    """An aligned 2^a-block cube is one octree subtree: exactly ONE
    contiguous range under hilbert/morton, exactly e² ranges under
    row-major (e < nt) — so hilbert ≤ row-major always, strictly
    whenever the cube is a proper subcube."""
    M = MS[data.draw(st.integers(0, len(MS) - 1))]
    T = 4
    nt = M // T
    a = data.draw(st.integers(0, nt.bit_length() - 1))
    e = 2 ** a  # cube edge, blocks
    pos = tuple(data.draw(st.integers(0, nt // e - 1)) * e for _ in range(3))
    roi = ROI(tuple(p * T for p in pos), tuple((p + e) * T for p in pos))

    counts = {k: len(roi_to_ranges(StoreLayout(M=M, T=T, kind=k), roi))
              for k in KINDS}
    assert counts["hilbert"] == 1 and counts["morton"] == 1
    assert counts["row_major"] == (e * e if e < nt else 1)
    assert counts["hilbert"] <= counts["row_major"]
    if e < nt and e > 1:
        assert counts["hilbert"] < counts["row_major"]


def test_merge_blocks_to_ranges_edge_cases():
    assert merge_blocks_to_ranges(np.array([])) == []
    assert merge_blocks_to_ranges(np.array([3])) == [(3, 4)]
    assert merge_blocks_to_ranges(np.array([5, 3, 4, 9, 3])) == [(3, 6), (9, 10)]
    assert ranges_to_blocks([]).size == 0
    np.testing.assert_array_equal(ranges_to_blocks([(1, 3), (7, 8)]), [1, 2, 7])


def test_roi_and_layout_validation():
    with pytest.raises(ValueError):
        ROI((0, 0, 0), (0, 4, 4))  # empty axis
    with pytest.raises(ValueError):
        ROI((0, 0), (4, 4))  # not 3-D
    with pytest.raises(ValueError):
        StoreLayout(M=10, T=4)  # T does not tile M
    with pytest.raises(ValueError):
        roi_to_ranges(StoreLayout(M=8, T=4), ROI((0, 0, 0), (9, 4, 4)))
    with pytest.raises(ValueError):
        QueryResult(status="bogus", roi=ROI((0, 0, 0), (1, 1, 1)))


def test_roi_model_accounting():
    lay = StoreLayout(M=16, T=4, kind="hilbert", channels=2)
    m = roi_model(lay, ROI((0, 0, 0), (8, 8, 8)))
    assert m["blocks_touched"] == 8 and m["ranges"] == 1
    assert m["bytes_read"] == 8 * 2 * 64 * 4
    assert m["payload_bytes"] == 2 * 512 * 4
    assert m["utilization"] == 1.0
    # unaligned box pays for whole blocks: utilization < 1
    m2 = roi_model(lay, ROI((1, 1, 1), (9, 9, 9)))
    assert m2["blocks_touched"] == 27 and m2["utilization"] < 1.0


# ---------------------------------------------------------------------------
# 2. extract_roi bit-identity vs the dense cube (ordering × boundary × C)
# ---------------------------------------------------------------------------

def _rois_for(M):
    return [ROI((0, 0, 0), (M, M, M)),             # whole cube
            ROI((0, 0, 0), (M // 2,) * 3),         # aligned octant
            ROI((1, 2, 3), (M - 3, M - 1, M)),     # unaligned box
            ROI((M - 1, 0, M // 2), (M, 1, M // 2 + 1))]  # single element line


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("rule,bc", [
    ("gol", "periodic"), ("gol", "neumann0"),
    ("wave", "periodic"), ("wave", mixed(k="neumann0")),
])
def test_extract_roi_bit_identical_to_dense_slice(kind, rule, bc):
    import jax.numpy as jnp

    from repro.stencil import ResidentPipeline

    M, T = 8, 4
    pipe = ResidentPipeline(M=M, T=T, rule=rule, bc=bc, kind=kind)
    cube = np.asarray(pipe.run(jnp.asarray(initial_state(rule, M, seed=1)), 2))
    store = np.asarray(pipe.to_blocks(jnp.asarray(cube)))
    layout = StoreLayout.from_pipeline(pipe)
    for roi in _rois_for(M):
        got = extract_roi(store, layout, roi)
        sl = tuple(slice(l, h) for l, h in zip(roi.lo, roi.hi))
        np.testing.assert_array_equal(got, cube[(Ellipsis,) + sl])


def test_extract_roi_skip_blocks_nan_fill():
    lay = StoreLayout(M=8, T=4, kind="hilbert")
    store = np.random.default_rng(0).standard_normal(
        (lay.nb, 4, 4, 4)).astype(np.float32)
    roi = ROI((0, 0, 0), (8, 4, 4))
    ranges = roi_to_ranges(lay, roi)
    skip = [int(ranges_to_blocks(ranges)[0])]
    out = extract_roi(store, lay, roi, ranges=ranges, skip_blocks=skip)
    assert np.isnan(out).sum() == 64  # exactly one block's footprint
    full = extract_roi(store, lay, roi)
    mask = ~np.isnan(out)
    np.testing.assert_array_equal(out[mask], full[mask])


# ---------------------------------------------------------------------------
# 3. the serving fault matrix
# ---------------------------------------------------------------------------

class FakeClock:
    """Injectable monotonic clock; ``sleep`` advances it (no real wait)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _service(kind="hilbert", M=16, T=4, C=1, **kw):
    rng = np.random.default_rng(7)
    lay = StoreLayout(M=M, T=T, kind=kind, channels=C)
    shape = ((lay.nb, T, T, T) if C == 1
             else (C, lay.nb, T, T, T))
    store = rng.standard_normal(shape).astype(np.float32)
    kw.setdefault("backoff_s", 1e-4)
    return StencilQueryService(store=store, layout=lay, **kw), store, lay


OCTANT = ROI((0, 0, 0), (8, 8, 8))       # 1 hilbert range at M=16/T=4
MULTI = ROI((0, 0, 0), (16, 8, 8))       # 8 row-major ranges


@pytest.mark.parametrize("C", [1, 2])
def test_query_ok_bit_identical(C):
    svc, store, lay = _service(C=C)
    r = svc.query(OCTANT)
    assert r.status == "ok" and r.complete and r.missing_ranges == ()
    assert r.payload.shape == ((8, 8, 8) if C == 1 else (2, 8, 8, 8))
    np.testing.assert_array_equal(r.payload, extract_roi(store, lay, OCTANT))
    assert len(r.ranges) == 1 and r.fetch_calls == 1  # contiguity economics


def test_cache_hits_and_disabled_cache():
    svc, _, lay = _service()
    r1 = svc.query(OCTANT)
    r2 = svc.query(OCTANT)
    assert r1.cache_misses == 8 and r1.fetch_calls == 1
    assert r2.cache_hits == 8 and r2.cache_misses == 0 and r2.fetch_calls == 0
    np.testing.assert_array_equal(r1.payload, r2.payload)
    assert svc.stats()["cached_blocks"] == 8

    svc0, _, _ = _service(cache_blocks=0)
    svc0.query(OCTANT)
    r = svc0.query(OCTANT)
    assert r.cache_hits == 0 and r.fetch_calls == 1  # every query refetches
    assert svc0.stats()["cached_blocks"] == 0


def test_cache_poison_quarantined_and_refetched():
    svc, store, lay = _service()
    svc.query(OCTANT)
    b = int(ranges_to_blocks(roi_to_ranges(lay, OCTANT))[0])
    assert svc.poison_cache(b)
    r = svc.query(OCTANT)
    assert r.status == "ok" and r.quarantined == 1
    assert r.cache_hits == 7 and r.cache_misses == 1  # only the bad block
    np.testing.assert_array_equal(r.payload, extract_roi(store, lay, OCTANT))
    assert svc.stats()["quarantined"] == 1
    # the quarantined block was re-fetched and re-cached clean
    r3 = svc.query(OCTANT)
    assert r3.cache_hits == 8 and r3.quarantined == 0


def test_transient_fetch_failures_recover():
    svc, store, lay = _service(max_retries=2)
    plan = ServeFaultPlan(fail_first=2)
    svc.fetch = plan.wrap_fetch(svc.fetch)
    r = svc.query(OCTANT)
    assert r.status == "ok" and r.retries == 2 and r.fetch_calls == 3
    np.testing.assert_array_equal(r.payload, extract_roi(store, lay, OCTANT))


def test_exhausted_retries_all_missing_is_error():
    svc, _, _ = _service(max_retries=2)
    plan = ServeFaultPlan(fail_first=99)
    svc.fetch = plan.wrap_fetch(svc.fetch)
    r = svc.query(OCTANT)
    assert r.status == "error" and not r.complete and r.payload is None
    assert r.missing_ranges == tuple(r.ranges)
    assert "injected fetch failure" in r.error


def test_exhausted_retries_partial_is_degraded_with_manifest():
    svc, store, lay = _service(kind="row_major", max_retries=2)
    plan = ServeFaultPlan(fail_first=3)  # kills exactly the first range
    svc.fetch = plan.wrap_fetch(svc.fetch)
    r = svc.query(MULTI)
    assert r.status == "degraded" and not r.complete
    assert len(r.ranges) == 8 and r.missing_ranges == (r.ranges[0],)
    # missing footprint is NaN; delivered footprint is bit-identical
    miss = np.isnan(r.payload)
    assert miss.sum() == (r.ranges[0][1] - r.ranges[0][0]) * 4 ** 3
    want = extract_roi(store, lay, MULTI)
    np.testing.assert_array_equal(r.payload[~miss], want[~miss])
    assert svc.stats()["degraded"] == 1


def test_bitflipped_fetch_caught_by_manifest_and_retried():
    svc, store, lay = _service(max_retries=2)
    plan = ServeFaultPlan(bitflip_first=1)
    svc.fetch = plan.wrap_fetch(svc.fetch)
    r = svc.query(OCTANT)
    assert r.status == "ok" and r.integrity_failures >= 1 and r.retries >= 1
    np.testing.assert_array_equal(r.payload, extract_roi(store, lay, OCTANT))


def test_bitflip_every_fetch_never_serves_wrong_bytes():
    svc, _, _ = _service(max_retries=1)
    plan = ServeFaultPlan(bitflip_first=99)
    svc.fetch = plan.wrap_fetch(svc.fetch)
    r = svc.query(OCTANT)
    assert r.status == "error" and r.payload is None  # typed, not corrupt
    assert "integrity failure" in r.error


def test_deadline_pressure_degrades_with_fake_clock():
    clock = FakeClock()
    svc, store, lay = _service(kind="row_major", clock=clock,
                               sleep=clock.advance, deadline_s=0.5)
    plan = ServeFaultPlan(slow_first=99, slow_s=0.2)
    svc.fetch = plan.wrap_fetch(svc.fetch, sleep=clock.advance)
    r = svc.query(MULTI)
    assert r.status == "degraded" and r.missing_ranges
    assert "deadline" in r.error
    assert r.elapsed_s >= 0.5  # but it returned — no hang
    # the two ranges that landed before the deadline are exact
    miss = np.isnan(r.payload)
    want = extract_roi(store, lay, MULTI)
    np.testing.assert_array_equal(r.payload[~miss], want[~miss])
    # a fresh unhurried query on the same (now slow-free) service is ok
    plan.slow_first = 0
    assert svc.query(MULTI).status == "ok"


def test_admission_control_sheds_typed_rejections():
    svc, _, _ = _service(max_in_flight=2, cache_blocks=0)
    base = svc.fetch
    entered = threading.Semaphore(0)
    release = threading.Event()

    def gated(a, b):
        entered.release()
        assert release.wait(10)
        return base(a, b)

    svc.fetch = gated
    with ThreadPoolExecutor(max_workers=2) as ex:
        futs = [ex.submit(svc.query, OCTANT, deadline_s=30) for _ in range(2)]
        assert entered.acquire(timeout=10) and entered.acquire(timeout=10)
        shed = [svc.query(OCTANT) for _ in range(4)]  # budget is full
        release.set()
        held = [f.result(timeout=30) for f in futs]
    assert [r.status for r in shed] == ["rejected"] * 4
    assert all(r.payload is None and "admission" in r.error for r in shed)
    assert [r.status for r in held] == ["ok", "ok"]
    assert svc.stats()["shed"] == 4 and svc.stats()["in_flight"] == 0


def test_query_batch_order_preserving_and_typed():
    svc, store, lay = _service()
    rois = [OCTANT, ROI((8, 8, 8), (16, 16, 16)), ROI((1, 2, 3), (5, 9, 13)),
            ROI((0, 0, 0), (16, 16, 16))]
    results = svc.query_batch(rois)
    assert [r.roi for r in results] == rois
    assert all(r.status in QUERY_STATUSES for r in results)
    assert all(r.status == "ok" for r in results)
    for roi, r in zip(rois, results):
        np.testing.assert_array_equal(r.payload, extract_roi(store, lay, roi))


def test_fault_plan_composes_under_batch():
    """Transient failures + one bitflip injected into a concurrent batch:
    every outcome typed, every delivered byte exact."""
    svc, store, lay = _service(max_retries=3)
    plan = ServeFaultPlan(fail_first=2, bitflip_first=1)
    svc.fetch = plan.wrap_fetch(svc.fetch)
    rois = [OCTANT, ROI((8, 0, 0), (16, 8, 8)), ROI((0, 8, 0), (8, 16, 8))]
    results = svc.query_batch(rois)
    assert all(r.status == "ok" for r in results)
    assert sum(r.retries for r in results) >= 3
    for roi, r in zip(rois, results):
        np.testing.assert_array_equal(r.payload, extract_roi(store, lay, roi))


def test_short_read_is_a_typed_fetch_error():
    svc, _, _ = _service(max_retries=0)
    svc.fetch = lambda a, b: np.zeros((1, 1, 4, 4, 4), np.float32)
    r = svc.query(OCTANT)
    assert r.status == "error" and "short read" in r.error


def test_fetch_error_is_runtime_error():
    assert issubclass(FetchError, RuntimeError)


# ---------------------------------------------------------------------------
# satellites: thread-safe LRU caches under the serving pool
# ---------------------------------------------------------------------------

def test_device_constant_thread_safe_under_hammer():
    from repro.core import layout as L

    nkeys = L._DEVICE_CONSTANTS_CAP // 2
    errs = []

    def worker(t):
        try:
            for i in range(200):
                k = ("tsafe-hammer", (t + i) % nkeys)
                v = L.device_constant(
                    k, lambda k=k: np.full((8,), k[1], np.float32))
                assert int(np.asarray(v)[0]) == k[1]
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert errs == []
    with L._DEVICE_CONSTANTS_LOCK:
        assert len(L._DEVICE_CONSTANTS) <= L._DEVICE_CONSTANTS_CAP
        for k in [k for k in L._DEVICE_CONSTANTS if k[0] == "tsafe-hammer"]:
            del L._DEVICE_CONSTANTS[k]  # don't leak into other tests


def test_row_plan_thread_safe_under_hammer():
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    idxs = [np.sort(rng.choice(4096, 256, replace=False)) for _ in range(16)]
    refs = [ops._row_plan(i, 64) for i in idxs]  # uncached ground truth
    errs = []

    def worker(t):
        try:
            for i in range(100):
                j = (t + i) % len(idxs)
                rows, pos = ops._row_plan(idxs[j], 64,
                                          plan_key=("tsafe", j))
                np.testing.assert_array_equal(rows, refs[j][0])
                np.testing.assert_array_equal(pos, refs[j][1])
        except Exception as e:  # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert errs == []


# ---------------------------------------------------------------------------
# satellite: the benchmarked ROI suite matches the model, hilbert strict
# ---------------------------------------------------------------------------

def test_benchmark_rows_match_model_and_hilbert_strictly_beats_row():
    from benchmarks.roi import ORDERINGS, roi_suite

    T = 8
    for M in (32, 64):
        for name, roi in roi_suite(M):
            counts = {k: roi_model(StoreLayout(M=M, T=T, kind=k), roi)
                      for k in ORDERINGS}
            # the acceptance criterion: strict on every benchmarked row
            assert counts["hilbert"]["ranges"] < counts["row_major"]["ranges"], \
                (M, name, counts)
            # geometry keys are curve-independent
            for k in ORDERINGS:
                assert counts[k]["blocks_touched"] == \
                    counts["hilbert"]["blocks_touched"]
                assert counts[k]["bytes_read"] == counts["hilbert"]["bytes_read"]


def test_benchmark_derived_strings_reproduce_model():
    from benchmarks import roi as bench

    for name, _us, derived in bench.rows(sizes=(32,)):
        # name: roi/extract_M{M}_T{T}_{kind}_{roi_name}
        tail = name.split("/", 1)[1][len("extract_"):]
        m_s, t_s, rest = tail.split("_", 2)
        kind = next(k for k in bench.ORDERINGS if rest.startswith(k))
        roi_name = rest[len(kind) + 1:]
        lay = StoreLayout(M=int(m_s[1:]), T=int(t_s[1:]), kind=kind)
        roi = dict(bench.roi_suite(lay.M))[roi_name]
        m = roi_model(lay, roi)
        d = dict(p.split("=") for p in derived.split(";"))
        assert int(d["roi_ranges"]) == m["ranges"]
        assert int(d["roi_blocks"]) == m["blocks_touched"]
        assert int(d["roi_bytes_read"]) == m["bytes_read"]
        assert int(d["roi_payload_bytes"]) == m["payload_bytes"]
        assert abs(float(d["utilization"]) - m["utilization"]) < 1e-3
