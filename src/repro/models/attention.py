"""Attention variants: GQA (+ sliding window), MLA; full-seq and decode.

Memory discipline: the full-sequence path never materialises an (S,S)
score tensor for long sequences — queries are processed in chunks under
``lax.scan`` (blockwise attention; O(C·S) live scores). Masks are
computed per chunk from positions, so the 32k prefill shapes fit the
dry-run memory analysis. The SFC-scheduled Pallas kernel
(kernels/flash_attn.py) is the TPU-deploy alternative for the same path
(``cfg.use_flash_kernel``); the jnp form is what GSPMD shards.

gemma3's 5:1 local:global pattern runs as ONE scanned layer stack: the
per-layer boolean ``is_global`` is a scan input selecting between the
windowed and full mask (and between the two RoPE bases) — no unrolling,
single attention pass per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import flash_attention

from .config import ModelConfig
from .layers import apply_rope, causal_window_mask, rope_freqs

__all__ = ["masked_sdpa", "gqa_attention", "gqa_decode", "mla_attention",
           "mla_decode", "rope_with_freqs", "select_freqs"]

_NEG = -1e30
_Q_CHUNK = 1024
_CHUNK_THRESHOLD = 4096


def rope_with_freqs(x, pos, freqs):
    """Rotary with explicit (possibly per-layer-selected) frequencies."""
    ang = pos[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def select_freqs(cfg: ModelConfig, is_global, hd: int | None = None):
    hd = hd or cfg.hd
    f_loc = jnp.asarray(rope_freqs(hd, cfg.rope_theta))
    f_glb = jnp.asarray(rope_freqs(hd, cfg.global_rope_theta))
    if cfg.sliding_window is None:
        return f_loc
    return jnp.where(is_global, f_glb, f_loc)


def _mask_for(posq, posk, window, is_global, causal=True):
    """(Sq,Sk) mask; window applies only when is_global is False."""
    if not causal:
        return jnp.ones((posq.shape[0], posk.shape[0]), bool)
    m = causal_window_mask(posq, posk, None)
    if window is not None:
        mloc = causal_window_mask(posq, posk, window)
        if is_global is None:
            m = mloc
        else:
            m = jnp.where(is_global, m, mloc)
    return m


def masked_sdpa(q, k, v, posq, posk, *, window=None, is_global=None,
                causal=True, q_chunk: int = _Q_CHUNK, score_spec=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd). f32 softmax.

    For Sq > threshold, scans q in chunks so live scores are O(C·Sk).
    ``score_spec`` pins the (B,H,Sq,Sk) score sharding (decode with a
    sequence-sharded cache: distributed partial softmax).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV

    def blk(qc, pq):
        """Grouped-GQA attention. Two HBM-traffic rules (both are what the
        MXU does natively): (1) queries reshaped to (KV, rep) groups so
        K/V are never materialised H/KV×; (2) score/output einsums take
        bf16 operands with f32 ACCUMULATION (preferred_element_type) —
        never cast the cache itself to f32 (XLA would carry a duplicate
        f32 cache through the decode loop)."""
        C = qc.shape[1]
        m = _mask_for(pq, posk, window, is_global, causal)
        qg = qc.reshape(B, C, KV, rep, hd)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                       preferred_element_type=jnp.float32) / np.sqrt(hd)
        s = jnp.where(m[None, None, None], s, _NEG)
        if score_spec is not None:
            from jax.sharding import PartitionSpec as P
            bspec, _, qspec, kspec = score_spec
            s = jax.lax.with_sharding_constraint(
                s, P(bspec, None, None, qspec, kspec))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, C, H, hd).astype(v.dtype)

    if Sq <= _CHUNK_THRESHOLD or Sq % q_chunk:
        return blk(q, posq)
    nq = Sq // q_chunk
    qr = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pr = posq.reshape(nq, q_chunk)

    def scan_fn(_, inp):
        qc, pq = inp
        return None, blk(qc, pq)

    _, ob = jax.lax.scan(scan_fn, None, (qr, pr))
    return ob.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _proj_qkv(p, x, cfg: ModelConfig):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype)).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype)).reshape(B, S, KV, hd)
    return q, k, v


def gqa_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                  is_global=None, pos: jnp.ndarray | None = None,
                  causal: bool = True) -> jnp.ndarray:
    """Full-sequence GQA (train/prefill). x: (B,S,D)."""
    B, S, D = x.shape
    q, k, v = _proj_qkv(p, x, cfg)
    if pos is None:
        pos = jnp.arange(S)
    freqs = select_freqs(cfg, is_global)
    q = rope_with_freqs(q, pos, freqs)
    k = rope_with_freqs(k, pos, freqs)
    if cfg.use_flash_kernel and causal and cfg.sliding_window is None:
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), True, cfg.flash_schedule,
                            128, 128).transpose(0, 2, 1, 3)
    else:
        o = masked_sdpa(q, k, v, pos, pos, window=cfg.sliding_window,
                        is_global=is_global, causal=causal)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype))


def gqa_decode(p: dict, x: jnp.ndarray, cache: dict, cur: jnp.ndarray,
               cfg: ModelConfig, *, is_global=None):
    """Single-token decode, one pass (mask/rope selected by flag)."""
    B = x.shape[0]
    q, k, v = _proj_qkv(p, x, cfg)
    posq = jnp.full((1,), cur, jnp.int32)
    freqs = select_freqs(cfg, is_global)
    q = rope_with_freqs(q, posq, freqs)
    k = rope_with_freqs(k, posq, freqs)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, cur, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, cur, 0, 0))
    posk = jnp.arange(ck.shape[1])
    o = masked_sdpa(q, ck, cv, posq, posk, window=cfg.sliding_window,
                    is_global=is_global, score_spec=cfg.score_spec)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": ck, "v": cv}


# ----------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV latent cache
# ----------------------------------------------------------------------

def _mla_parts(p, x, cfg: ModelConfig):
    mla = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    nope, rope = mla.qk_nope_dim, mla.qk_rope_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = jnp.einsum("bsd,dh->bsh", x, p["w_dkv"].astype(x.dtype))
    c_kv, k_rope = dkv[..., :mla.kv_lora_rank], dkv[..., mla.kv_lora_rank:]
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, posq, posk, cfg,
                q_chunk: int = _Q_CHUNK):
    """Blockwise attention through the latent cache."""
    mla = cfg.mla
    B, Sk = c_kv.shape[:2]
    Sq = q_nope.shape[1]
    H = cfg.n_heads
    nope, rope, vd = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_dim
    q_rope = rope_with_freqs(q_rope, posq, jnp.asarray(
        rope_freqs(rope, cfg.rope_theta)))
    k_rope = rope_with_freqs(k_rope[..., None, :], posk, jnp.asarray(
        rope_freqs(rope, cfg.rope_theta)))[..., 0, :]
    k_nope = jnp.einsum("bsc,ch->bsh", c_kv, p["w_uk"].astype(c_kv.dtype))
    k_nope = k_nope.reshape(B, Sk, H, nope)
    v = jnp.einsum("bsc,ch->bsh", c_kv, p["w_uv"].astype(c_kv.dtype))
    v = v.reshape(B, Sk, H, vd)
    scale = 1.0 / np.sqrt(nope + rope)

    def blk(qn, qr, pq):
        s = (jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bqhd,bkd->bhqk", qr, k_rope,
                          preferred_element_type=jnp.float32)) * scale
        m = causal_window_mask(pq, posk, None)
        s = jnp.where(m[None, None], s, _NEG)
        if Sq == 1 and cfg.score_spec is not None:  # decode
            from jax.sharding import PartitionSpec as P
            s = jax.lax.with_sharding_constraint(s, P(*cfg.score_spec))
        pr = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", pr.astype(v.dtype), v,
                          preferred_element_type=jnp.float32).astype(v.dtype)

    if Sq <= _CHUNK_THRESHOLD or Sq % q_chunk:
        o = blk(q_nope, q_rope, posq)
    else:
        nq = Sq // q_chunk
        qn = q_nope.reshape(B, nq, q_chunk, H, nope).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, nq, q_chunk, H, rope).transpose(1, 0, 2, 3, 4)
        pr_ = posq.reshape(nq, q_chunk)

        def scan_fn(_, inp):
            a, b, c = inp
            return None, blk(a, b, c)

        _, ob = jax.lax.scan(scan_fn, None, (qn, qr, pr_))
        o = ob.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, vd)
    return o.reshape(B, Sq, H * vd)


def mla_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                  pos: jnp.ndarray | None = None, **_) -> jnp.ndarray:
    B, S, _ = x.shape
    if pos is None:
        pos = jnp.arange(S)
    qn, qr, c_kv, k_rope = _mla_parts(p, x, cfg)
    o = _mla_attend(p, qn, qr, c_kv, k_rope, pos, pos, cfg)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype))


def mla_decode(p: dict, x: jnp.ndarray, cache: dict, cur: jnp.ndarray,
               cfg: ModelConfig, **_):
    """cache: {c_kv: (B,Smax,lora), k_rope: (B,Smax,rope)} — compressed."""
    qn, qr, c_kv_new, k_rope_new = _mla_parts(p, x, cfg)
    ck = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, cur, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, cur, 0))
    posq = jnp.full((1,), cur, jnp.int32)
    posk = jnp.arange(ck.shape[1])
    o = _mla_attend(p, qn, qr, ck, kr, posq, posk, cfg)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"c_kv": ck, "k_rope": kr}
