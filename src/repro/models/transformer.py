"""Model assembly: param-def trees + scan-over-layers forward/decode.

All families share the machinery: per-layer parameters are stacked on a
leading ``layers`` axis and the layer body runs under ``lax.scan`` (keeps
HLO size O(1) in depth — essential for the 40-cell dry-run) wrapped in
``jax.checkpoint`` for training remat.

Families:
  dense   — GQA decoder LM (smollm, deepseek-coder, phi4, gemma3 w/ 5:1
            local:global pattern via per-layer scan flags)
  moe     — dense attention or MLA + fine-grained MoE FFN (deepseek-moe,
            deepseek-v2-lite); first_k_dense layers use a dense FFN
  ssm     — Mamba2/SSD stack (mamba2-2.7b)
  hybrid  — Mamba2 stack + ONE weight-shared GQA block applied every
            `period` layers (zamba2)
  encdec  — whisper: bidirectional encoder over stubbed frame embeddings,
            causal decoder w/ cross attention (RoPE in decoder — learned
            448-pos table replaced to support the 32k stress shapes; see
            DESIGN.md)
  vlm     — internvl: stubbed ViT patch embeddings -> projector -> LM
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .config import ModelConfig
from .layers import (causal_window_mask, embed, rmsnorm, rope_freqs, swiglu,
                     softmax_cross_entropy, unembed)
from .mamba2 import mamba2_block, mamba2_decode
from .moe import moe_ffn
from .params import ParamDef

__all__ = ["model_defs", "forward", "forward_hidden", "prefill",
           "decode_step", "cache_defs", "loss_fn"]

L = "layers"


# ======================================================================
# Param defs
# ======================================================================

def _attn_defs(cfg: ModelConfig, n_layers: int | None, *, heads=None,
               kv=None) -> dict:
    """GQA projection defs; n_layers=None -> unstacked (shared block)."""
    H = heads or cfg.n_heads
    KV = kv or cfg.n_kv_heads
    hd = cfg.hd
    D = cfg.d_model
    lead = () if n_layers is None else (n_layers,)
    la = () if n_layers is None else (L,)
    o_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    return {
        "wq": ParamDef(lead + (D, H * hd), la + ("embed", "heads")),
        "wk": ParamDef(lead + (D, KV * hd), la + ("embed", "kv_heads")),
        "wv": ParamDef(lead + (D, KV * hd), la + ("embed", "kv_heads")),
        "wo": ParamDef(lead + (H * hd, D), la + ("heads", "embed"), scale=o_scale),
    }


def _mla_defs(cfg: ModelConfig, n_layers: int) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    o_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    return {
        "wq": ParamDef((n_layers, D, H * (m.qk_nope_dim + m.qk_rope_dim)),
                       (L, "embed", "heads")),
        "w_dkv": ParamDef((n_layers, D, m.kv_lora_rank + m.qk_rope_dim),
                          (L, "embed", None)),
        "w_uk": ParamDef((n_layers, m.kv_lora_rank, H * m.qk_nope_dim),
                         (L, None, "heads")),
        "w_uv": ParamDef((n_layers, m.kv_lora_rank, H * m.v_dim),
                         (L, None, "heads")),
        "wo": ParamDef((n_layers, H * m.v_dim, D), (L, "heads", "embed"),
                       scale=o_scale),
    }


def _mlp_defs(D: int, F: int, n_layers: int | None, o_scale: float) -> dict:
    lead = () if n_layers is None else (n_layers,)
    la = () if n_layers is None else (L,)
    return {
        "gate": ParamDef(lead + (D, F), la + ("embed", "ffn")),
        "up": ParamDef(lead + (D, F), la + ("embed", "ffn")),
        "down": ParamDef(lead + (F, D), la + ("ffn", "embed"), scale=o_scale),
    }


def _norm(D: int, n_layers: int | None, name_unused=None) -> ParamDef:
    lead = () if n_layers is None else (n_layers,)
    la = () if n_layers is None else (L,)
    return ParamDef(lead + (D,), la + (None,), init="zeros")


def _moe_defs(cfg: ModelConfig, n_layers: int) -> dict:
    mo = cfg.moe
    D, E, Fe = cfg.d_model, mo.n_routed, mo.d_ff_expert
    Fs = mo.n_shared * Fe
    o_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    return {
        "router": ParamDef((n_layers, D, E), (L, "embed", None)),
        # experts: EP only (expert axis on "model"). FSDP-sharding the
        # embed dim too would make every expert GEMM a partial-sum
        # all-reduce over "data" of the full activation (§Perf log).
        "w1": ParamDef((n_layers, E, D, Fe), (L, "expert", None, None)),
        "w3": ParamDef((n_layers, E, D, Fe), (L, "expert", None, None)),
        "w2": ParamDef((n_layers, E, Fe, D), (L, "expert", None, None),
                       scale=o_scale),
        "shared_gate": ParamDef((n_layers, D, Fs), (L, "embed", "ffn")),
        "shared_up": ParamDef((n_layers, D, Fs), (L, "embed", "ffn")),
        "shared_down": ParamDef((n_layers, Fs, D), (L, "ffn", "embed"),
                                scale=o_scale),
    }


def _mamba_defs(cfg: ModelConfig, n_layers: int) -> dict:
    ssm = cfg.ssm
    D = cfg.d_model
    d_inner = ssm.expand * D
    gn = ssm.n_groups * ssm.d_state
    H = d_inner // ssm.head_dim
    d_in_proj = 2 * d_inner + 2 * gn + H
    conv_dim = d_inner + 2 * gn
    o_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    return {
        "norm": _norm(D, n_layers),
        "in_proj": ParamDef((n_layers, D, d_in_proj), (L, "embed", "inner")),
        "conv_w": ParamDef((n_layers, ssm.conv_width, conv_dim),
                           (L, None, "conv")),
        "conv_b": ParamDef((n_layers, conv_dim), (L, "conv"), init="zeros"),
        "a_log": ParamDef((n_layers, H), (L, None), init="custom:a_log"),
        "d_skip": ParamDef((n_layers, H), (L, None), init="ones"),
        "dt_bias": ParamDef((n_layers, H), (L, None), init="custom:dt_bias"),
        "gnorm": ParamDef((n_layers, d_inner), (L, "inner"), init="zeros"),
        "out_proj": ParamDef((n_layers, d_inner, D), (L, "inner", "embed"),
                             scale=o_scale),
    }


def _decoder_layer_defs(cfg: ModelConfig, n_layers: int, *, use_moe: bool,
                        cross: bool = False) -> dict:
    D = cfg.d_model
    o_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    d = {"norm1": _norm(D, n_layers), "norm2": _norm(D, n_layers)}
    if cfg.mla is not None:
        d.update(_mla_defs(cfg, n_layers))
    else:
        d.update(_attn_defs(cfg, n_layers))
    if cross:
        d["norm_x"] = _norm(D, n_layers)
        d["cross"] = _attn_defs(cfg, n_layers, kv=cfg.n_heads)  # cross is MHA
    if use_moe:
        d["moe"] = _moe_defs(cfg, n_layers)
    else:
        d.update(_mlp_defs(D, cfg.d_ff, n_layers, o_scale))
    return d


def model_defs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_padded
    defs: dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed")),
        "final_norm": _norm(D, None),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((D, V), ("embed", "vocab"))
    fam = cfg.family
    if fam in ("dense", "vlm"):
        defs["layers"] = _decoder_layer_defs(cfg, cfg.n_layers, use_moe=False)
        if fam == "vlm":
            defs["projector"] = {
                "w1": ParamDef((cfg.vlm.vit_dim, D), (None, "embed")),
                "norm": ParamDef((cfg.vlm.vit_dim,), (None,), init="zeros"),
            }
    elif fam == "moe":
        k = cfg.moe.first_k_dense
        dense_cfg_ff = cfg.moe.d_ff_expert * (cfg.moe.top_k + cfg.moe.n_shared)
        if k:
            d = _decoder_layer_defs(cfg, k, use_moe=False)
            # first-k dense layers use the "active-equivalent" FFN width
            o_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
            d.update(_mlp_defs(D, dense_cfg_ff, k, o_scale))
            defs["dense_layers"] = d
        defs["layers"] = _decoder_layer_defs(cfg, cfg.n_layers - k, use_moe=True)
    elif fam == "ssm":
        defs["layers"] = _mamba_defs(cfg, cfg.n_layers)
    elif fam == "hybrid":
        defs["layers"] = _mamba_defs(cfg, cfg.n_layers)
        hy = cfg.hybrid
        shared = {"norm1": _norm(D, None), "norm2": _norm(D, None)}
        shared.update(_attn_defs(cfg, None, heads=hy.shared_n_heads,
                                 kv=hy.shared_n_kv_heads))
        shared.update(_mlp_defs(D, hy.shared_d_ff, None,
                                0.02 / np.sqrt(2 * cfg.n_layers)))
        defs["shared_block"] = shared
    elif fam == "encdec":
        defs["layers"] = _decoder_layer_defs(cfg, cfg.n_layers, use_moe=False,
                                             cross=True)
        defs["enc_layers"] = _decoder_layer_defs(cfg, cfg.encdec.n_enc_layers,
                                                 use_moe=False)
        defs["enc_final_norm"] = _norm(D, None)
    else:
        raise ValueError(fam)
    return defs


# ======================================================================
# Forward (full sequence)
# ======================================================================

def _layer_flags(cfg: ModelConfig) -> np.ndarray:
    return np.array([cfg.layer_is_global(i) for i in range(cfg.n_layers)],
                    dtype=np.bool_)


def _act_constraint(x, cfg: ModelConfig):
    """Optional residual-stream sharding (set by the launcher per mesh).

    cfg.act_spec is a PartitionSpec-able tuple for (B, S, D) — typically
    (batch_axes, "model", None): sequence-sharded residuals (Megatron-SP
    style) so scan-saved remat residuals are 1/TP the size.
    """
    if cfg.act_spec is None or x.ndim != 3:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*cfg.act_spec))


def _attn_layer_train(p, x, cfg: ModelConfig, is_global, pos, *, cross_kv=None):
    """One decoder layer (attention + FFN/MoE). Returns (x, aux)."""
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        a = attn.mla_attention(p, h, cfg, pos=pos)
    else:
        a = attn.gqa_attention(p, h, cfg, is_global=is_global, pos=pos)
    x = x + a
    if cross_kv is not None:
        hx = rmsnorm(x, p["norm_x"], cfg.norm_eps)
        x = x + _cross_attention(p["cross"], hx, cross_kv, cfg)
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        f, aux = moe_ffn(p["moe"], h2, cfg)
    else:
        f = swiglu(p, h2)
    return _act_constraint(x + f, cfg), aux


def _cross_attention(p, h, enc_kv, cfg: ModelConfig):
    """Decoder cross-attention over precomputed encoder K/V."""
    B, S, D = h.shape
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(h.dtype)).reshape(B, S, H, hd)
    k, v = enc_kv
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", pr.astype(v.dtype), v)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd),
                      p["wo"].astype(h.dtype))


def _scan_layers(layer_fn, stacked, x, xs_extra=None, remat=True):
    body = layer_fn
    if remat == "dots":
        # save weight-GEMM outputs (no recompute of FSDP-gathered matmuls
        # in bwd), recompute the cheap elementwise chain
        body = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        body = jax.checkpoint(layer_fn)

    def scan_body(carry, inp):
        x, aux = carry
        pl, extra = inp
        x, a = body(pl, x, extra)
        return (x, aux + a), None

    xs = (stacked, xs_extra)
    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux


def _mamba_layer(p, x, cfg):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return _act_constraint(x + mamba2_block(p, h, cfg), cfg)


def _encode(params, frames, cfg: ModelConfig, remat=True):
    """whisper encoder: bidirectional attention over frame embeddings."""
    x = frames.astype(jnp.dtype(cfg.activation_dtype))
    S = x.shape[1]
    pos = jnp.arange(S)

    def layer(p, x, _):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        a = attn.gqa_attention(p, h, cfg, pos=pos, causal=False)
        x = x + a
        h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
        return x + swiglu(p, h2), jnp.zeros((), jnp.float32)

    nl = cfg.encdec.n_enc_layers
    x, _ = _scan_layers(layer, params["enc_layers"], x,
                        jnp.zeros((nl,), bool), remat)
    return rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def forward_hidden(params: dict, batch: dict, cfg: ModelConfig,
                   remat: bool = True):
    """Full-sequence trunk -> (hidden (B,S,D) after final norm, aux_loss)."""
    adt = jnp.dtype(cfg.activation_dtype)
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, adt)
    if cfg.family == "vlm":
        pn = params["projector"]
        patches = rmsnorm(batch["patches"].astype(adt), pn["norm"], cfg.norm_eps)
        pe = jnp.einsum("bpv,vd->bpd", patches, pn["w1"].astype(adt))
        x = jnp.concatenate([pe, x], axis=1)
    S = x.shape[1]
    pos = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def layer(p, x, _):
            return _mamba_layer(p, x, cfg), jnp.zeros((), jnp.float32)
        x, _ = _scan_layers(layer, params["layers"], x,
                            jnp.zeros((cfg.n_layers,), bool), remat)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, x, cfg, remat)
    elif cfg.family == "encdec":
        enc = _encode(params, batch["frames"], cfg, remat)
        ek, ev = _enc_kv_all(params, enc, cfg)

        def layer(p, x, ekv):
            return _attn_layer_train(p, x, cfg, jnp.asarray(True), pos,
                                     cross_kv=ekv)
        x, _ = _scan_layers(layer, params["layers"], x, (ek, ev), remat)
    else:
        flags = jnp.asarray(_layer_flags(cfg))
        if cfg.family == "moe" and cfg.moe.first_k_dense:
            k = cfg.moe.first_k_dense

            def dlayer(p, x, fl):
                return _attn_layer_train(p, x, cfg, fl, pos)
            x, a1 = _scan_layers(dlayer, params["dense_layers"], x, flags[:k],
                                 remat)
            aux = aux + a1
            flags = flags[k:]

        def layer(p, x, fl):
            return _attn_layer_train(p, x, cfg, fl, pos)
        x, a2 = _scan_layers(layer, params["layers"], x, flags, remat)
        aux = aux + a2

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _unembed_w(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def _mask_pad(logits, cfg: ModelConfig):
    """-inf the padded vocab tail (vocab_pad_multiple) wherever logits
    surface, so padding never wins a softmax/argmax."""
    if cfg.vocab_padded == cfg.vocab:
        return logits
    keep = jnp.arange(logits.shape[-1]) < cfg.vocab
    return jnp.where(keep, logits, -1e30)


def forward(params: dict, batch: dict, cfg: ModelConfig, remat: bool = True):
    """Full-sequence forward -> (logits f32 (B,S,V), aux_loss).

    Materialises the full logits — use only for small configs/tests;
    loss_fn and prefill use the chunked/last-position paths.
    """
    x, aux = forward_hidden(params, batch, cfg, remat)
    return _mask_pad(unembed(_unembed_w(params, cfg), x), cfg), aux


def prefill(params: dict, batch: dict, cfg: ModelConfig, remat: bool = False):
    """Inference prefill: trunk + LAST-position logits only (B,V)."""
    x, _ = forward_hidden(params, batch, cfg, remat)
    return _mask_pad(unembed(_unembed_w(params, cfg), x[:, -1]), cfg)


def _chunked_ce(hidden, w_un, labels, mask, cfg, chunk: int = 512):
    """CE without materialising (B,S,V): scan over sequence chunks."""
    B, S, D = hidden.shape
    if S % chunk:
        logits = _mask_pad(unembed(w_un, hidden), cfg)
        return softmax_cross_entropy(logits, labels, mask)
    ns = S // chunk
    h = hidden.reshape(B, ns, chunk, D).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, ns, chunk).transpose(1, 0, 2)
    mk = (jnp.ones_like(labels, jnp.float32) if mask is None
          else mask.astype(jnp.float32))
    mk = mk.reshape(B, ns, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        hc, lc, mc = inp
        logits = _mask_pad(unembed(w_un, hc), cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (h, lb, mk))
    return tot / jnp.maximum(cnt, 1.0)


def _enc_kv_all(params, enc, cfg):
    """Precompute cross K/V for every decoder layer: (L,B,F,H,hd) each."""
    H, hd = cfg.n_heads, cfg.hd
    B, F, D = enc.shape

    def per_layer(pl):
        k = jnp.einsum("bfd,dh->bfh", enc, pl["wk"].astype(enc.dtype))
        v = jnp.einsum("bfd,dh->bfh", enc, pl["wv"].astype(enc.dtype))
        return k.reshape(B, F, H, hd), v.reshape(B, F, H, hd)

    return jax.vmap(per_layer)(params["layers"]["cross"])


def _hybrid_forward(params, x, cfg: ModelConfig, remat=True):
    """zamba2: scan mamba segments; shared GQA block between segments."""
    hy = cfg.hybrid
    period = hy.period
    nl = cfg.n_layers
    pos = jnp.arange(x.shape[1])
    shared = params["shared_block"]

    def mamba_layer(p, x, _):
        return _mamba_layer(p, x, cfg), jnp.zeros((), jnp.float32)

    def shared_apply(x):
        h = rmsnorm(x, shared["norm1"], cfg.norm_eps)
        scfg = _shared_cfg(cfg)
        a = attn.gqa_attention(shared, h, scfg, pos=pos)
        x = x + a
        h2 = rmsnorm(x, shared["norm2"], cfg.norm_eps)
        return x + swiglu(shared, h2)

    start = 0
    while start < nl:
        stop = min(start + period, nl)
        seg = jax.tree.map(lambda a: a[start:stop], params["layers"])
        x, _ = _scan_layers(mamba_layer, seg, x,
                            jnp.zeros((stop - start,), bool), remat)
        if stop < nl or stop % period == 0:
            x = shared_apply(x)
        start = stop
    return x


def _shared_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    hy = cfg.hybrid
    return dataclasses.replace(cfg, n_heads=hy.shared_n_heads,
                               n_kv_heads=hy.shared_n_kv_heads,
                               head_dim=cfg.d_model // hy.shared_n_heads,
                               mla=None, sliding_window=None)


def loss_fn(params, batch, cfg: ModelConfig, remat: bool = True):
    hidden, aux = forward_hidden(params, batch, cfg, remat)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # hidden covers [patches; text] — score text positions only
        hidden = hidden[:, cfg.vlm.n_patches:]
    mask = batch.get("loss_mask")
    ce = _chunked_ce(hidden, _unembed_w(params, cfg), labels, mask, cfg)
    return ce + aux, (ce, aux)


# ======================================================================
# Decode (single token with cache)
# ======================================================================

def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ParamDef tree for the decode cache (reuses the sharding machinery)."""
    adt = "cache"  # marker; dtype chosen at init
    nl = cfg.n_layers
    B = batch
    hd = cfg.hd

    def kv(n_layers, kvh, seq):
        return {
            "k": ParamDef((n_layers, B, seq, kvh, hd),
                          (L, "batch", "seq", "kv_heads", None), init="zeros"),
            "v": ParamDef((n_layers, B, seq, kvh, hd),
                          (L, "batch", "seq", "kv_heads", None), init="zeros"),
        }

    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"layers": kv(nl, cfg.n_kv_heads, max_len)}
    if fam == "moe":
        k = cfg.moe.first_k_dense
        m = cfg.mla
        if m is not None:
            def mla_cache(n):
                return {
                    "c_kv": ParamDef((n, B, max_len, m.kv_lora_rank),
                                     (L, "batch", "seq", None), init="zeros"),
                    "k_rope": ParamDef((n, B, max_len, m.qk_rope_dim),
                                       (L, "batch", "seq", None), init="zeros"),
                }
            d = {"layers": mla_cache(nl - k)}
            if k:
                d["dense_layers"] = mla_cache(k)
            return d
        d = {"layers": kv(nl - k, cfg.n_kv_heads, max_len)}
        if k:
            d["dense_layers"] = kv(k, cfg.n_kv_heads, max_len)
        return d
    if fam in ("ssm", "hybrid"):
        ssm = cfg.ssm
        d_inner = ssm.expand * cfg.d_model
        gn = ssm.n_groups * ssm.d_state
        H = d_inner // ssm.head_dim
        conv_dim = d_inner + 2 * gn
        d = {"layers": {
            "conv": ParamDef((nl, B, ssm.conv_width - 1, conv_dim),
                             (L, "batch", None, "conv"), init="zeros"),
            "ssm": ParamDef((nl, B, H, ssm.head_dim, ssm.d_state),
                            (L, "batch", "inner", None, None), init="zeros"),
        }}
        if fam == "hybrid":
            n_app = _n_shared_apps(cfg)
            hy = cfg.hybrid
            d["shared"] = {
                "k": ParamDef((n_app, B, max_len, hy.shared_n_kv_heads,
                               cfg.d_model // hy.shared_n_heads),
                              (None, "batch", "seq", "kv_heads", None),
                              init="zeros"),
                "v": ParamDef((n_app, B, max_len, hy.shared_n_kv_heads,
                               cfg.d_model // hy.shared_n_heads),
                              (None, "batch", "seq", "kv_heads", None),
                              init="zeros"),
            }
        return d
    if fam == "encdec":
        F = cfg.encdec.n_frames
        d = {"layers": kv(nl, cfg.n_kv_heads, max_len)}
        d["cross"] = {
            "k": ParamDef((nl, B, F, cfg.n_heads, hd),
                          (L, "batch", None, "heads", None), init="zeros"),
            "v": ParamDef((nl, B, F, cfg.n_heads, hd),
                          (L, "batch", None, "heads", None), init="zeros"),
        }
        return d
    raise ValueError(fam)


def _n_shared_apps(cfg: ModelConfig) -> int:
    hy = cfg.hybrid
    n = 0
    start = 0
    while start < cfg.n_layers:
        stop = min(start + hy.period, cfg.n_layers)
        if stop < cfg.n_layers or stop % hy.period == 0:
            n += 1
        start = stop
    return n


def _attn_layer_decode(p, x, cl, cur, cfg, is_global, cross_kv=None):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, cl_new = attn.mla_decode(p, h, cl, cur, cfg)
    else:
        a, cl_new = attn.gqa_decode(p, h, cl, cur, cfg, is_global=is_global)
    x = x + a
    if cross_kv is not None:
        hx = rmsnorm(x, p["norm_x"], cfg.norm_eps)
        x = x + _cross_attention(p["cross"], hx, cross_kv, cfg)
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps)
    if "moe" in p:
        f, _ = moe_ffn(p["moe"], h2, cfg)
    else:
        f = swiglu(p, h2)
    return x + f, cl_new


def _scan_decode(layer_fn, stacked, cache, x, xs_extra):
    def body(x, inp):
        pl, cl, extra = inp
        x, cl_new = layer_fn(pl, x, cl, extra)
        return x, cl_new

    x, new_cache = jax.lax.scan(body, x, (stacked, cache, xs_extra))
    return x, new_cache


def decode_step(params: dict, cache: dict, batch: dict, cfg: ModelConfig):
    """One-token decode. batch: {tokens:(B,1), cur:() int32} -> (logits, cache)."""
    adt = jnp.dtype(cfg.activation_dtype)
    tokens, cur = batch["tokens"], batch["cur"]
    x = embed(params["embed"], tokens, adt)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "vlm", "moe"):
        flags = jnp.asarray(_layer_flags(cfg))
        if fam == "moe" and cfg.moe.first_k_dense:
            k = cfg.moe.first_k_dense

            def dl(p, x, cl, fl):
                return _attn_layer_decode(p, x, cl, cur, cfg, fl)
            x, nc = _scan_decode(dl, params["dense_layers"],
                                 cache["dense_layers"], x, flags[:k])
            new_cache["dense_layers"] = nc
            flags = flags[k:]
        else:
            flags = flags[:]

        def lyr(p, x, cl, fl):
            return _attn_layer_decode(p, x, cl, cur, cfg, fl)
        x, nc = _scan_decode(lyr, params["layers"], cache["layers"], x, flags)
        new_cache["layers"] = nc
    elif fam == "ssm":
        def lyr(p, x, cl, _):
            h = rmsnorm(x, p["norm"], cfg.norm_eps)
            o, cl_new = mamba2_decode(p, h, cl, cfg)
            return x + o, cl_new
        x, nc = _scan_decode(lyr, params["layers"], cache["layers"], x,
                             jnp.zeros((cfg.n_layers,), bool))
        new_cache["layers"] = nc
    elif fam == "hybrid":
        x, nc, nshared = _hybrid_decode(params, cache, x, cur, cfg)
        new_cache["layers"] = nc
        new_cache["shared"] = nshared
    elif fam == "encdec":
        def lyr(p, x, cl, ekv):
            return _attn_layer_decode(p, x, cl, cur, cfg, jnp.asarray(True),
                                      cross_kv=ekv)
        x, nc = _scan_decode(lyr, params["layers"], cache["layers"], x,
                             (cache["cross"]["k"], cache["cross"]["v"]))
        new_cache["layers"] = nc
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_un = _unembed_w(params, cfg)
    return _mask_pad(unembed(w_un, x), cfg), new_cache


def _hybrid_decode(params, cache, x, cur, cfg):
    hy = cfg.hybrid
    nl = cfg.n_layers
    shared = params["shared_block"]
    scfg = _shared_cfg(cfg)

    def mlyr(p, x, cl, _):
        h = rmsnorm(x, p["norm"], cfg.norm_eps)
        o, cl_new = mamba2_decode(p, h, cl, cfg)
        return x + o, cl_new

    new_layer_cache = []
    new_shared = {"k": [], "v": []}
    app = 0
    start = 0
    while start < nl:
        stop = min(start + hy.period, nl)
        seg_p = jax.tree.map(lambda a: a[start:stop], params["layers"])
        seg_c = jax.tree.map(lambda a: a[start:stop], cache["layers"])
        x, nc = _scan_decode(mlyr, seg_p, seg_c, x,
                             jnp.zeros((stop - start,), bool))
        new_layer_cache.append(nc)
        if stop < nl or stop % hy.period == 0:
            h = rmsnorm(x, shared["norm1"], cfg.norm_eps)
            cl = {"k": cache["shared"]["k"][app], "v": cache["shared"]["v"][app]}
            a, cl_new = attn.gqa_decode(shared, h, cl, cur, scfg)
            x = x + a
            h2 = rmsnorm(x, shared["norm2"], cfg.norm_eps)
            x = x + swiglu(shared, h2)
            new_shared["k"].append(cl_new["k"])
            new_shared["v"].append(cl_new["v"])
            app += 1
        start = stop
    nc_all = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_layer_cache)
    shared_all = {k: jnp.stack(v, 0) for k, v in new_shared.items()}
    return x, nc_all, shared_all
