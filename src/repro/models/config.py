"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 64
    n_shared: int = 2
    top_k: int = 6
    d_ff_expert: int = 1408
    first_k_dense: int = 1          # deepseek: first layer(s) use dense FFN
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 64                 # SSD chunk length


@dataclass(frozen=True)
class HybridConfig:
    """zamba2: shared attention block applied every `period` SSM layers."""
    period: int = 6
    shared_d_ff: int = 8192
    shared_n_heads: int = 32
    shared_n_kv_heads: int = 32


@dataclass(frozen=True)
class EncDecConfig:
    """whisper: encoder over stubbed frame embeddings."""
    n_enc_layers: int = 12
    n_frames: int = 1500            # precomputed conv-frontend output length


@dataclass(frozen=True)
class VLMConfig:
    """internvl: stubbed ViT patch embeddings + projector."""
    n_patches: int = 256
    vit_dim: int = 3200


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # pad the embedding/unembedding tables to a multiple of this so odd
    # vocabs (51865, 50280) stay TP-shardable; padded logits are masked
    # to -inf everywhere they surface (§Perf backlog #3)
    vocab_pad_multiple: int = 1
    # attention pattern
    sliding_window: int | None = None
    global_every: int | None = None  # gemma3: every Nth layer is global
    global_rope_theta: float = 1e6
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    use_flash_kernel: bool = False  # Pallas attention (TPU deploy flag)
    flash_schedule: str = "morton"
    # residual-stream sharding for (B, S, D) activations; set by the
    # launcher per mesh, e.g. (("pod","data"), "model", None) = batch +
    # sequence sharding (Megatron-SP). None -> let GSPMD propagate.
    act_spec: tuple | None = None
    # decode-attention score sharding for (B, H, 1, Sk); set by the
    # launcher to match the sequence-sharded KV cache, e.g.
    # (batch_axes, None, None, "model") — pins GSPMD to distributed
    # partial-softmax attention instead of all-gathering the cache.
    score_spec: tuple | None = None
    # expert-parallel mesh axis for MoE dispatch buffers; pins the
    # (B, E, C, ·) buffers to P(batch, ep_axis, …) so expert GEMMs are
    # EP-sharded instead of replicated (§Perf log).
    ep_axis: str | None = None

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_is_global(self, layer_idx: int) -> bool:
        """gemma3 local:global pattern; non-windowed models are all-global."""
        if self.sliding_window is None:
            return True
        if self.global_every is None:
            return False
        return (layer_idx + 1) % self.global_every == 0
