"""Model zoo: config -> (defs, init, specs, loss, decode) bundle."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import transformer as tfm
from .config import ModelConfig
from .params import (abstract_params, count_params, init_params,
                     partition_specs)

__all__ = ["Model", "build_model"]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters
    def defs(self):
        return tfm.model_defs(self.cfg)

    def init(self, key, dtype=jnp.float32):
        return init_params(self.defs(), key, dtype)

    def abstract(self, dtype=jnp.float32):
        return abstract_params(self.defs(), dtype)

    def specs(self, extra_rules=None):
        return partition_specs(self.defs(), extra_rules=extra_rules)

    def n_params(self) -> int:
        return count_params(self.defs())

    def n_active_params(self) -> int:
        """Active params per token (MoE discount) for 6ND model flops."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.family != "moe":
            return total
        mo = cfg.moe
        from .params import _leaf_paths
        inactive = 0
        for path, d in _leaf_paths(self.defs()):
            if len(path) >= 2 and path[-2] == "moe" and path[-1] in ("w1", "w2", "w3"):
                import numpy as np
                full = int(np.prod(d.shape))
                inactive += full * (mo.n_routed - mo.top_k) // mo.n_routed
        return total - inactive

    # ---- training
    def loss(self, params, batch, remat: bool = True):
        return tfm.loss_fn(params, batch, self.cfg, remat)

    def forward(self, params, batch, remat: bool = False):
        return tfm.forward(params, batch, self.cfg, remat)

    def prefill(self, params, batch, remat: bool = False):
        """Last-position logits (B,V) — the inference prefill step."""
        return tfm.prefill(params, batch, self.cfg, remat)

    # ---- serving
    def cache_defs(self, batch: int, max_len: int):
        return tfm.cache_defs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return init_params(self.cache_defs(batch, max_len),
                           jax.random.PRNGKey(0), dtype)

    def abstract_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return abstract_params(self.cache_defs(batch, max_len), dtype)

    def cache_specs(self, batch: int, max_len: int, extra_rules=None):
        return partition_specs(self.cache_defs(batch, max_len),
                               extra_rules=extra_rules)

    def decode(self, params, cache, batch):
        return tfm.decode_step(params, cache, batch, self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
