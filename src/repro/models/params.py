"""Parameter definition trees: shapes, logical sharding axes, init.

Every parameter is declared once as a ``ParamDef(shape, axes, scale)``;
``init_params`` materialises the tree, ``abstract_params`` produces
ShapeDtypeStructs (for the no-allocation dry-run) and ``partition_specs``
produces the PartitionSpec tree from logical-axis rules — guaranteed
consistent because all three walk the same defs.

Logical axes (MaxText-style):
  embed     — model width (FSDP-sharded over "data")
  heads     — attention heads × head_dim (TP over "model")
  kv_heads  — kv heads × head_dim
  ffn       — MLP hidden (TP over "model")
  vocab     — vocabulary (TP over "model")
  expert    — MoE expert bank (EP over "model")
  inner     — SSM inner dim (TP over "model")
  layers    — stacked scan axis (never sharded)
  (None)    — replicated
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["ParamDef", "LOGICAL_RULES", "init_params", "abstract_params",
           "partition_specs", "count_params"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis per dim
    scale: float = 0.02               # normal stddev; 0 -> zeros; 1.0 -> ones
    init: str = "normal"              # normal | zeros | ones | custom:<name>

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


LOGICAL_RULES: dict[str, Any] = {
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "expert": "model",
    "inner": "model",
    "layers": None,
    "conv": "model",
}


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, ParamDef):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from _leaf_paths(tree[k], prefix + (k,))


def _custom_init(name: str, shape, key):
    if name == "a_log":      # mamba2: A in [1, 16], stored as log
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u)
    if name == "dt_bias":    # softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, shape, jnp.float32, np.log(1e-3), np.log(1e-1))
        dt = jnp.exp(u)
        return dt + jnp.log(-jnp.expm1(-dt))
    raise ValueError(name)


def init_params(defs, key, dtype=jnp.float32):
    """Materialise a ParamDef tree into arrays."""
    paths = list(_leaf_paths(defs))
    keys = jax.random.split(key, len(paths))
    flat = {}
    for (path, d), k in zip(paths, keys):
        if d.init == "zeros" or d.scale == 0.0:
            v = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dtype)
        elif d.init.startswith("custom:"):
            v = _custom_init(d.init.split(":", 1)[1], d.shape, k).astype(dtype)
        else:
            v = (jax.random.normal(k, d.shape, jnp.float32) * d.scale).astype(dtype)
        flat[path] = v
    return _unflatten(flat)


def abstract_params(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation) for .lower()."""
    flat = {p: jax.ShapeDtypeStruct(d.shape, dtype) for p, d in _leaf_paths(defs)}
    return _unflatten(flat)


def partition_specs(defs, rules=None, extra_rules=None):
    rules = dict(LOGICAL_RULES if rules is None else rules)
    if extra_rules:
        rules.update(extra_rules)
    flat = {}
    for path, d in _leaf_paths(defs):
        spec = tuple(rules.get(a) if a is not None else None for a in d.axes)
        flat[path] = P(*spec)
    return _unflatten(flat)


def count_params(defs) -> int:
    return sum(int(np.prod(d.shape)) for _, d in _leaf_paths(defs))


def _unflatten(flat: dict[tuple, Any]):
    root: dict = {}
    for path, v in flat.items():
        node = root
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = v
    return root
