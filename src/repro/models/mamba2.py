"""Mamba2 (SSD — state-space duality) block, chunked train + O(1) decode.

Faithful to arXiv:2405.21060: the sequence is processed in chunks; within
a chunk the recurrence is computed as a masked (L×L) matmul (the "dual"
quadratic form — MXU-friendly), and a lax.scan over chunk-final states
carries the recurrence between chunks. Decode keeps a constant-size
(H, P, N) state per layer — the reason long_500k is assigned to the
SSM/hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import rmsnorm

__all__ = ["ssd_chunked", "ssd_decode_step", "mamba2_block", "mamba2_decode"]


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD scan. x:(B,T,H,P) dt:(B,T,H) A:(H,)<0 Bm/Cm:(B,T,G,N) -> y:(B,T,H,P).

    h_t = exp(dt_t A) h_{t-1} + dt_t x_t ⊗ B_t ;  y_t = C_t · h_t
    """
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = chunk
    assert T % L == 0, (T, L)
    nc = T // L
    rep = H // G
    x = x.astype(jnp.float32)
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    dt = dt.astype(jnp.float32)

    xd = x * dt[..., None]
    la = dt * A[None, None, :]                       # log decay per step
    xc = xd.reshape(Bsz, nc, L, H, P)
    Bc = Bh.reshape(Bsz, nc, L, H, N)
    Cc = Ch.reshape(Bsz, nc, L, H, N)
    lac = la.reshape(Bsz, nc, L, H)
    cums = jnp.cumsum(lac, axis=2)                   # inclusive cumulative

    # intra-chunk dual form: M[t,s] = exp(cums_t - cums_s)·(C_t·B_s), s<=t
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (B,nc,Lt,Ls,H)
    tri = np.tril(np.ones((L, L), dtype=bool))
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc)
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0) * scores
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", M, xc)

    # chunk-final local states + inter-chunk scan
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)        # (B,nc,L,H)
    S = jnp.einsum("bclh,bclhn,bclhp->bchpn", decay_to_end, Bc, xc)
    chunk_decay = jnp.exp(cums[:, :, -1, :])                 # (B,nc,H)

    def scan_fn(h, inp):
        cd, s = inp
        return h * cd[..., None, None] + s, h

    _, h_enter = jax.lax.scan(
        scan_fn, jnp.zeros((Bsz, H, P, N), jnp.float32),
        (chunk_decay.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)
    y_inter = jnp.einsum("bclh,bclhn,bchpn->bclhp",
                         jnp.exp(cums), Cc, h_enter)
    return (y_intra + y_inter).reshape(Bsz, T, H, P)


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """One token. h:(B,H,P,N) x:(B,H,P) dt:(B,H) Bm/Cm:(B,G,N) -> (y, h')."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(dt.astype(jnp.float32) * A[None, :])         # (B,H)
    u = jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32) * dt[..., None], Bh)
    h_new = h * a[..., None, None] + u
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    return y, h_new


def _split_proj(p, xin, cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    gn = ssm.n_groups * ssm.d_state
    H = d_inner // ssm.head_dim
    zxbcdt = jnp.einsum("...d,dk->...k", xin, p["in_proj"].astype(xin.dtype))
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:2 * d_inner + 2 * gn]
    dt = zxbcdt[..., 2 * d_inner + 2 * gn:]
    return z, xbc, dt, d_inner, gn, H


def _conv_train(xbc, w, b):
    """Causal depthwise conv over time. xbc:(B,T,C) w:(W,C) b:(C,)."""
    W = w.shape[0]
    pads = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for t in range(W):
        out = out + pads[:, t:t + xbc.shape[1]].astype(jnp.float32) * \
            w[t][None, None].astype(jnp.float32)
    return jax.nn.silu(out + b[None, None].astype(jnp.float32)).astype(xbc.dtype)


def mamba2_block(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence Mamba2 mixer (pre-norm residual applied by caller)."""
    ssm = cfg.ssm
    Bsz, T, D = x.shape
    z, xbc, dtp, d_inner, gn, H = _split_proj(p, x, cfg)
    xbc = _conv_train(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_inner]
    Bm = xbc[..., d_inner:d_inner + gn].reshape(Bsz, T, ssm.n_groups, ssm.d_state)
    Cm = xbc[..., d_inner + gn:].reshape(Bsz, T, ssm.n_groups, ssm.d_state)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) +
                         p["dt_bias"][None, None].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(Bsz, T, H, ssm.head_dim)
    y = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(Bsz, T, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["gnorm"], cfg.norm_eps)
    return jnp.einsum("...k,kd->...d", y, p["out_proj"].astype(x.dtype))


def mamba2_decode(p: dict, x: jnp.ndarray, cache: dict, cfg: ModelConfig):
    """One-token decode. x:(B,1,D); cache {conv:(B,W-1,C), ssm:(B,H,P,N)}."""
    ssm = cfg.ssm
    Bsz = x.shape[0]
    z, xbc, dtp, d_inner, gn, H = _split_proj(p, x[:, 0], cfg)
    # conv with rolling state
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,W,C)
    w, b = p["conv_w"], p["conv_b"]
    xbc_c = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                   w.astype(jnp.float32)) + b[None].astype(jnp.float32)
    ).astype(x.dtype)
    new_conv = conv_in[:, 1:]
    xs = xbc_c[..., :d_inner]
    Bm = xbc_c[..., d_inner:d_inner + gn].reshape(Bsz, ssm.n_groups, ssm.d_state)
    Cm = xbc_c[..., d_inner + gn:].reshape(Bsz, ssm.n_groups, ssm.d_state)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(Bsz, H, ssm.head_dim)
    y, h_new = ssd_decode_step(cache["ssm"].astype(jnp.float32), xh, dt, A, Bm, Cm)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"].astype(x.dtype))[:, None]
    return out, {"conv": new_conv, "ssm": h_new.astype(cache["ssm"].dtype)}
