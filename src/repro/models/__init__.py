"""LM substrate: configs, layers, attention variants, MoE, SSD, assembly."""

from .config import (  # noqa: F401
    ModelConfig, MLAConfig, MoEConfig, SSMConfig, HybridConfig,
    EncDecConfig, VLMConfig,
)
from .zoo import Model, build_model  # noqa: F401
from .params import (  # noqa: F401
    ParamDef, init_params, abstract_params, partition_specs, count_params,
    LOGICAL_RULES,
)
