"""Fine-grained MoE (DeepSeek style): shared + routed experts, top-k.

Dispatch is the sort-based fixed-capacity scheme (production JAX MoE):
flatten the (token, k) assignments, stable-sort by expert, place each
assignment at its rank within the expert's capacity-C buffer (overflow
drops — standard), run one batched per-expert GEMM, and scatter-add back
weighted by the router gate. Compute cost ≈ T·k·cf·D·F (not E·T·D·F).

Expert weights carry the ``expert`` logical axis → EP over the "model"
mesh axis; GSPMD turns the dispatch into an all-to-all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import swiglu

__all__ = ["moe_ffn", "router_aux_loss"]


def router_aux_loss(probs: jnp.ndarray, ids: jnp.ndarray, n_experts: int):
    """Switch-style load-balance loss: E · <f_e>·<p_e>."""
    f = jnp.mean(jax.nn.one_hot(ids, n_experts, dtype=jnp.float32), axis=(0, 1))
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def _dispatch_row(xt, gate, ids, E: int, K: int, C: int):
    """Capacity-C sort dispatch for ONE sequence (T_row, D) -> (E, C, D).

    All index math is row-local, so under vmap the batch axis stays
    sharded and no global argsort/gather crosses device boundaries —
    the cross-device movement is confined to the expert-axis einsums
    (= the EP all-to-all), which is the production MoE pattern.
    """
    T, D = xt.shape
    eid = ids.reshape(-1)                                   # (T*K,)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    tok_s = order // K
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    rank = jnp.arange(T * K) - first
    keep = rank < C
    slot_e = jnp.where(keep, eid_s, E)                      # drop -> OOB
    slot_c = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E + 1, C, D), xt.dtype)
    buf = buf.at[slot_e, slot_c].set(xt[tok_s], mode="drop")
    return buf[:E], (order, tok_s, slot_e, slot_c, keep)


def _combine_row(ye, gate, idxs, T: int, K: int, dtype):
    order, tok_s, slot_e, slot_c, keep = idxs
    E = ye.shape[0]
    vals = ye[slot_e.clip(0, E - 1), slot_c]                # (T*K, D)
    w = (gate.reshape(-1)[order] * keep.astype(jnp.float32))[:, None]
    out = jnp.zeros((T, ye.shape[-1]), jnp.float32).at[tok_s].add(
        vals.astype(jnp.float32) * w)
    return out.astype(dtype)


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar).

    p: router (D,E); w1,w3: (E,D,Fe); w2: (E,Fe,D);
       shared_{gate,up}: (D, n_shared·Fe); shared_down: (n_shared·Fe, D).

    Dispatch is ROW-LOCAL (vmapped over batch) with per-row capacity
    C = ceil(S·K/E·cf): batch-sharded activations never cross shards in
    the index ops; expert parallelism happens in the (b,e,c,·)×(e,·,·)
    einsums, which GSPMD lowers to the EP all-to-all.
    """
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.n_routed, moe.top_k
    C = max(int(np.ceil(S * K / E * moe.capacity_factor)), 1)

    if cfg.act_spec is not None:
        # gather the sequence axis inside the shard: dispatch indexing is
        # row-local by construction, so only batch sharding remains
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(
            x, P(cfg.act_spec[0], None, None))

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, K)                     # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    aux = router_aux_loss(probs.reshape(-1, E), ids.reshape(-1, K),
                          E) * moe.aux_loss_coef

    buf, idxs = jax.vmap(
        lambda xr, gr, ir: _dispatch_row(xr, gr, ir, E, K, C))(x, gate, ids)

    def _ep(t):
        """Pin (B, E, …) buffers to batch×expert sharding: the buf
        constraint IS the EP all-to-all; without it GSPMD replicates the
        expert GEMMs over the model axis."""
        if cfg.ep_axis is None:
            return t
        from jax.sharding import PartitionSpec as P
        b_ax = cfg.act_spec[0] if cfg.act_spec is not None else None
        return jax.lax.with_sharding_constraint(
            t, P(b_ax, cfg.ep_axis, *([None] * (t.ndim - 2))))

    buf = _ep(buf)
    # ---- per-expert GEMMs (EP: expert axis sharded over "model")
    g = jnp.einsum("becd,edf->becf", buf, p["w1"].astype(buf.dtype),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("becd,edf->becf", buf, p["w3"].astype(buf.dtype),
                   preferred_element_type=jnp.float32)
    h = _ep((jax.nn.silu(g) * u).astype(buf.dtype))
    ye = jnp.einsum("becf,efd->becd", h, p["w2"].astype(buf.dtype),
                    preferred_element_type=jnp.float32).astype(buf.dtype)
    # (§Perf log: resharding ye to batch-only before the combine was
    # tried two ways — plain b-spec turned into a full all-gather, and a
    # b×ep split blew up to 2.2TB of all-gather as GSPMD fought the
    # constraint. Keeping ye EP-sharded and letting the combine gather
    # cross the EP axis measured best; a shard_map ragged all-to-all is
    # the next step beyond GSPMD here.)
    ye = _ep(ye)
    out = jax.vmap(
        lambda yr, gr, ir: _combine_row(yr, gr, ir, S, K, x.dtype)
    )(ye, gate, idxs)

    # ---- shared experts (always-on dense path)
    shared = swiglu({"gate": p["shared_gate"], "up": p["shared_up"],
                     "down": p["shared_down"]}, x)
    out = out + shared
    if cfg.act_spec is not None:
        from jax.sharding import PartitionSpec as P
        out = jax.lax.with_sharding_constraint(out, P(*cfg.act_spec))
    return out, aux
