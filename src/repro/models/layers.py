"""Shared neural layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rmsnorm", "rope_freqs", "apply_rope", "swiglu", "embed",
           "unembed", "softmax_cross_entropy", "causal_window_mask"]


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * rms) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, hd); pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))             # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs       # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP. p: {gate: (D,F), up: (D,F), down: (F,D)}."""
    g = jnp.einsum("...d,df->...f", x, p["gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(x.dtype))


def embed(w: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(w, tokens, axis=0).astype(dtype)


def unembed(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in float32 (numerics) — w: (D, V)."""
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                      w.astype(jnp.float32))


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: jnp.ndarray | None = None):
    """Mean next-token CE. logits: (B,S,V) f32; labels: (B,S) int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def causal_window_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                       window: int | None) -> jnp.ndarray:
    """(..., Sq, Sk) boolean mask: causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m = m & (k_pos[..., None, :] > q_pos[..., :, None] - window)
    return m
