"""JAX relayout operations: apply an ordering to real arrays.

These are the executable counterparts of core/orderings.py — pure-JAX
gathers with *static* (numpy, trace-time) permutations, so XLA sees plain
gathers/reshapes and can fuse them.

The TPU-native form stores the cube as ``(n_blocks, T, T, T)`` with blocks
ordered along the curve (DESIGN.md §2): the curve ordering is then a
property of the memory layout, exactly as in the paper, and a Pallas
kernel that walks blocks sequentially walks HBM contiguously.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .orderings import OrderingSpec, path_to_rmo, rmo_to_path, _check_pow2, _flat_index

__all__ = [
    "apply_ordering", "undo_ordering",
    "block_order", "blockize", "unblockize", "blockize_with_halo",
]


def apply_ordering(x: jnp.ndarray, spec: OrderingSpec) -> jnp.ndarray:
    """Reorder an (M,M,M) cube into a flat (M³,) path-ordered vector."""
    M = x.shape[0]
    assert x.shape == (M, M, M), x.shape
    q = path_to_rmo(spec, M)  # path pos -> rmo
    return x.reshape(-1)[q]


def undo_ordering(v: jnp.ndarray, spec: OrderingSpec, M: int) -> jnp.ndarray:
    """Inverse of :func:`apply_ordering`."""
    p = rmo_to_path(spec, M)  # rmo -> path pos
    return v[p].reshape(M, M, M)


@functools.lru_cache(maxsize=64)
def block_order(kind: str, nt: int) -> np.ndarray:
    """Order of T³-tile *block coordinates* along a curve.

    Returns (nt³, 3) int array: row t holds the (bk,bi,bj) visited at path
    position t by ordering ``kind`` over the nt×nt×nt block grid.
    """
    _check_pow2(nt)
    kk, ii, jj = np.meshgrid(*(np.arange(nt, dtype=np.uint64),) * 3, indexing="ij")
    kk, ii, jj = kk.ravel(), ii.ravel(), jj.ravel()
    pidx = _flat_index(kind, kk, ii, jj, nt).astype(np.int64)
    out = np.empty((nt ** 3, 3), dtype=np.int64)
    out[pidx, 0] = kk
    out[pidx, 1] = ii
    out[pidx, 2] = jj
    out.setflags(write=False)
    return out


def blockize(x: jnp.ndarray, T: int, kind: str = "morton") -> jnp.ndarray:
    """(M,M,M) -> (nb, T, T, T) with blocks in ``kind`` curve order."""
    M = x.shape[0]
    nt = M // T
    assert nt * T == M
    bo = block_order(kind, nt)
    x6 = x.reshape(nt, T, nt, T, nt, T).transpose(0, 2, 4, 1, 3, 5)  # (nt,nt,nt,T,T,T)
    flat = x6.reshape(nt ** 3, T, T, T)
    lin = bo[:, 0] * nt * nt + bo[:, 1] * nt + bo[:, 2]
    return flat[lin]


def unblockize(blocks: jnp.ndarray, M: int, kind: str = "morton") -> jnp.ndarray:
    """Inverse of :func:`blockize`."""
    nb, T = blocks.shape[0], blocks.shape[1]
    nt = M // T
    assert nb == nt ** 3
    bo = block_order(kind, nt)
    lin = bo[:, 0] * nt * nt + bo[:, 1] * nt + bo[:, 2]
    inv = np.empty(nb, dtype=np.int64)
    inv[lin] = np.arange(nb)
    x6 = blocks[inv].reshape(nt, nt, nt, T, T, T).transpose(0, 3, 1, 4, 2, 5)
    return x6.reshape(M, M, M)


def blockize_with_halo(x: jnp.ndarray, T: int, g: int, kind: str = "morton",
                       periodic: bool = True) -> jnp.ndarray:
    """(M,M,M) -> (nb, T+2g, T+2g, T+2g), curve-ordered, halos included.

    This is the pack step feeding kernels/stencil3d.py: each block carries
    its own halo so the kernel needs no neighbour communication. Halo
    duplication factor is ((T+2g)/T)³.
    """
    M = x.shape[0]
    nt = M // T
    assert nt * T == M
    mode = "wrap" if periodic else "edge"
    xp = jnp.pad(x, g, mode=mode)
    bo = block_order(kind, nt)
    # static window gather: start offsets per block
    starts = bo * T  # in padded coords the halo window starts at bo*T
    w = T + 2 * g
    rng = np.arange(w)
    kk = starts[:, 0][:, None] + rng[None, :]           # (nb, w)
    ii = starts[:, 1][:, None] + rng[None, :]
    jj = starts[:, 2][:, None] + rng[None, :]
    return xp[kk[:, :, None, None], ii[:, None, :, None], jj[:, None, None, :]]
