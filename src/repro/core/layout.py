"""JAX relayout operations: apply an ordering to real arrays.

These are the executable counterparts of core/orderings.py — pure-JAX
gathers with *static* (numpy, trace-time) permutations, so XLA sees plain
gathers/reshapes and can fuse them.

The TPU-native form stores the cube as ``(n_blocks, T, T, T)`` with blocks
ordered along the curve (DESIGN.md §2): the curve ordering is then a
property of the memory layout, exactly as in the paper, and a Pallas
kernel that walks blocks sequentially walks HBM contiguously.
"""

from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from .orderings import OrderingSpec, path_to_rmo, rmo_to_path, _check_pow2, _flat_index

__all__ = [
    "apply_ordering", "undo_ordering", "device_constant",
    "block_order", "blockize", "unblockize", "blockize_with_halo",
    "blockize_fields", "unblockize_fields", "store_spec",
]


_DEVICE_CONSTANTS: dict = {}
_DEVICE_CONSTANTS_CAP = 256
# The serving path (serve/service.py) queries from a thread pool while
# pipelines trace on the main thread; every read-modify-write of the
# LRU dict must hold this lock (move-to-end + eviction are not atomic).
_DEVICE_CONSTANTS_LOCK = threading.RLock()


def device_constant(key, build):
    """Memoised device copy of a precomputed (numpy) table.

    Re-wrapping cached numpy tables at every trace made each jit embed a
    fresh device constant; memoising the jnp array lets repeated jits
    reuse one buffer. Creating a device array is only safe *outside*
    tracing (inside jit/shard_map traces ``jnp.asarray`` yields a trace-
    local tracer — caching it would leak), so under a trace this returns
    the numpy table unmemoised — exactly the seed behaviour — while
    eager call sites (e.g. Gol3d.__post_init__) populate the cache for
    every later trace to reuse.

    key:   hashable identity of the table
    build: zero-arg callable producing the numpy array (cheap: the
           numpy side is lru_cached upstream)

    Eviction is LRU: a hit moves the entry to the back of the (insertion
    -ordered) dict, so hot permutation/neighbour tables survive a full
    sweep of one-off keys; eviction pops the front. Device buffers are
    large (an M=256 permutation is 64 MiB), hence the cap.

    Thread-safe: concurrent misses on the same key may both build (the
    build is pure — last insert wins, benign), but the dict itself is
    only ever mutated under the lock, so a concurrent sweep can never
    corrupt the LRU order or lose entries mid-eviction.
    """
    with _DEVICE_CONSTANTS_LOCK:
        hit = _DEVICE_CONSTANTS.get(key)
        if hit is not None:
            _DEVICE_CONSTANTS[key] = _DEVICE_CONSTANTS.pop(key)  # move-to-end
            return hit
    arr = build()
    if jax.core.trace_state_clean():
        arr = jnp.asarray(arr)
        with _DEVICE_CONSTANTS_LOCK:
            while len(_DEVICE_CONSTANTS) >= _DEVICE_CONSTANTS_CAP:
                _DEVICE_CONSTANTS.pop(next(iter(_DEVICE_CONSTANTS)))
            _DEVICE_CONSTANTS[key] = arr
    return arr


def _perm_device(spec: OrderingSpec, M: int, inverse: bool):
    """Device-resident copy of the (int32) permutation, created once."""
    return device_constant(
        ("perm", spec, M, inverse),
        lambda: rmo_to_path(spec, M) if inverse else path_to_rmo(spec, M))


def apply_ordering(x: jnp.ndarray, spec: OrderingSpec) -> jnp.ndarray:
    """Reorder an (M,M,M) cube into a flat (M³,) path-ordered vector."""
    M = x.shape[0]
    assert x.shape == (M, M, M), x.shape
    q = _perm_device(spec, M, False)  # path pos -> rmo
    return x.reshape(-1)[q]


def undo_ordering(v: jnp.ndarray, spec: OrderingSpec, M: int) -> jnp.ndarray:
    """Inverse of :func:`apply_ordering`."""
    p = _perm_device(spec, M, True)  # rmo -> path pos
    return v[p].reshape(M, M, M)


@functools.lru_cache(maxsize=64)
def block_order(kind: str, nt: int) -> np.ndarray:
    """Order of T³-tile *block coordinates* along a curve.

    Returns (nt³, 3) int array: row t holds the (bk,bi,bj) visited at path
    position t by ordering ``kind`` over the nt×nt×nt block grid.
    """
    _check_pow2(nt)
    if nt == 1:  # single-block grid: every curve is trivial
        if kind not in ("row_major", "column_major", "morton", "hilbert"):
            raise ValueError(f"unknown simple ordering {kind!r}")
        out = np.zeros((1, 3), dtype=np.int64)
        out.setflags(write=False)
        return out
    kk, ii, jj = np.meshgrid(*(np.arange(nt, dtype=np.uint64),) * 3, indexing="ij")
    kk, ii, jj = kk.ravel(), ii.ravel(), jj.ravel()
    pidx = _flat_index(kind, kk, ii, jj, nt).astype(np.int64)
    out = np.empty((nt ** 3, 3), dtype=np.int64)
    out[pidx, 0] = kk
    out[pidx, 1] = ii
    out[pidx, 2] = jj
    out.setflags(write=False)
    return out


def _block_perm(kind: str, nt: int, inverse: bool) -> np.ndarray:
    bo = block_order(kind, nt)
    lin = (bo[:, 0] * nt * nt + bo[:, 1] * nt + bo[:, 2]).astype(np.int32)
    if not inverse:
        return lin
    inv = np.empty(nt ** 3, dtype=np.int32)
    inv[lin] = np.arange(nt ** 3, dtype=np.int32)
    return inv


def _block_perm_device(kind: str, nt: int, inverse: bool):
    """Cached device copy of the block permutation (path↔linear), int32."""
    return device_constant(("blockperm", kind, nt, inverse),
                           lambda: _block_perm(kind, nt, inverse))


def store_spec(kind: str, T: int) -> OrderingSpec:
    """The element ordering realised by the ``(nb, T, T, T)`` block store.

    ``blockize(x, T, kind).ravel()`` equals
    ``apply_ordering(x, store_spec(kind, T))`` exactly: blocks follow the
    ``kind`` curve, elements inside a block are row-major — i.e. the
    TPU-native store *is* a hybrid ordering (paper §2.3). This identity
    is what lets the surface machinery (core/surfaces.py, ops.pack_surface)
    pack halo faces straight out of the resident store: the store is just
    path-ordered state under this spec.
    """
    return OrderingSpec("hybrid", tile=T, outer=kind, inner="row_major")


def _check_blockable(M: int, T: int) -> int:
    """nt of an (M,M,M) cube split into T³ blocks — a clear error, not a
    bare assert: the layout boundary is where an elastic restore first
    meets a mismatched (M, T) target (DESIGN.md §10)."""
    nt, rem = divmod(M, T)
    if rem or nt < 1:
        raise ValueError(f"block edge T={T} does not tile cube edge M={M}")
    return nt


def blockize(x: jnp.ndarray, T: int, kind: str = "morton") -> jnp.ndarray:
    """(M,M,M) -> (nb, T, T, T) with blocks in ``kind`` curve order."""
    M = x.shape[0]
    if x.shape != (M, M, M):
        raise ValueError(f"blockize needs a cubic (M,M,M) state, "
                         f"got {x.shape}")
    nt = _check_blockable(M, T)
    x6 = x.reshape(nt, T, nt, T, nt, T).transpose(0, 2, 4, 1, 3, 5)  # (nt,nt,nt,T,T,T)
    flat = x6.reshape(nt ** 3, T, T, T)
    return flat[_block_perm_device(kind, nt, False)]


def unblockize(blocks: jnp.ndarray, M: int, kind: str = "morton") -> jnp.ndarray:
    """Inverse of :func:`blockize`."""
    nb, T = blocks.shape[0], blocks.shape[1]
    nt = _check_blockable(M, T)
    if nb != nt ** 3:
        raise ValueError(f"store has {nb} blocks, M={M}, T={T} "
                         f"implies {nt ** 3}")
    x6 = blocks[_block_perm_device(kind, nt, True)]
    x6 = x6.reshape(nt, nt, nt, T, T, T).transpose(0, 3, 1, 4, 2, 5)
    return x6.reshape(M, M, M)


def blockize_fields(fields: jnp.ndarray, T: int,
                    kind: str = "morton") -> jnp.ndarray:
    """(C,M,M,M) stacked fields -> (C, nb, T, T, T) multi-field block store.

    The C-channel store of DESIGN.md §9: every channel shares **one**
    block permutation (the ``kind`` curve over the nt³ block grid), so
    the whole multi-field state is curve-ordered by a single gather and
    the per-block neighbour/boundary tables apply to all channels alike.
    A 3-D input is promoted to C=1 and returned as ``(1, nb, T, T, T)``.
    """
    if fields.ndim == 3:
        fields = fields[None]
    C, M = fields.shape[0], fields.shape[1]
    if fields.shape != (C, M, M, M):
        raise ValueError(f"blockize_fields needs (C,M,M,M) stacked "
                         f"fields, got {fields.shape}")
    nt = _check_blockable(M, T)
    x7 = fields.reshape(C, nt, T, nt, T, nt, T).transpose(0, 1, 3, 5, 2, 4, 6)
    flat = x7.reshape(C, nt ** 3, T, T, T)
    return jnp.take(flat, _block_perm_device(kind, nt, False), axis=1)


def unblockize_fields(store: jnp.ndarray, M: int,
                      kind: str = "morton") -> jnp.ndarray:
    """Inverse of :func:`blockize_fields`: (C, nb, T³) -> (C, M, M, M)."""
    C, nb, T = store.shape[0], store.shape[1], store.shape[2]
    nt = _check_blockable(M, T)
    if nb != nt ** 3:
        raise ValueError(f"store has {nb} blocks, M={M}, T={T} "
                         f"implies {nt ** 3}")
    x7 = jnp.take(store, _block_perm_device(kind, nt, True), axis=1)
    x7 = x7.reshape(C, nt, nt, nt, T, T, T).transpose(0, 1, 4, 2, 5, 3, 6)
    return x7.reshape(C, M, M, M)


def blockize_with_halo(x: jnp.ndarray, T: int, g: int, kind: str = "morton",
                       periodic: bool = True, bc=None) -> jnp.ndarray:
    """(M,M,M) -> (nb, T+2g, T+2g, T+2g), curve-ordered, halos included.

    This is the pack step feeding kernels/stencil3d.py: each block carries
    its own halo so the kernel needs no neighbour communication. Halo
    duplication factor is ((T+2g)/T)³.

    ``bc`` (core.boundary.BoundarySpec or kind string) selects the ghost
    extension of the repack pipeline and overrides ``periodic`` when
    given; the bare ``periodic=False`` legacy toggle is edge replication
    (i.e. neumann0).
    """
    from .boundary import NEUMANN0, PERIODIC, pad_cube

    M = x.shape[0]
    nt = M // T
    assert nt * T == M
    if bc is None:
        bc = PERIODIC if periodic else NEUMANN0
    xp = pad_cube(x, g, bc)
    bo = block_order(kind, nt)
    # static window gather: start offsets per block
    starts = bo * T  # in padded coords the halo window starts at bo*T
    w = T + 2 * g
    rng = np.arange(w)
    kk = starts[:, 0][:, None] + rng[None, :]           # (nb, w)
    ii = starts[:, 1][:, None] + rng[None, :]
    jj = starts[:, 2][:, None] + rng[None, :]
    return xp[kk[:, :, None, None], ii[:, None, :, None], jj[:, None, None, :]]
