"""Physical boundary conditions for the stencil pipelines (DESIGN.md §8).

The paper's experiments — and the SEM locality study (arXiv:2104.08416)
it builds on — run on *physical* domains whose edges do not wrap. This
module is the one definition of that contract, shared by every pipeline
form (repack, resident, fused, distributed) and their jnp oracles:

- ``periodic``         — wrap at the domain edge (the torus default);
- ``dirichlet(value)`` — ghost sites hold a fixed value at all times;
- ``neumann0``         — zero normal gradient: ghost sites replicate the
  nearest in-domain plane (clamp-copy, ``jnp.pad(mode="edge")``).

A :class:`BoundarySpec` is frozen and hashable so it can ride jit static
arguments and cache keys exactly like an ``OrderingSpec``. Everything
downstream — the clamped neighbour tables (core/neighbors.py), the
in-window ghost refresh (kernels/rules.apply_window_bc), the mesh-edge
shell fill (stencil/halo.exchange_shell) and the exchange-surface
accounting (stencil/pipeline.py) — keys off the one ``kind`` string
defined here.

Per-face **mixed contracts** (DESIGN.md §8): a physical channel or slab
domain is clamped along one axis and periodic along the others (e.g. a
duct: clamped k, periodic i/j). :class:`MixedBoundary` carries one
:class:`BoundarySpec` per grid axis in ``(k, i, j)`` order; every
consumer reads the per-axis contract through the shared ``axes``
property — a plain :class:`BoundarySpec` exposes itself three times —
so uniform and mixed runs flow through identical code. On a multi-field
store (DESIGN.md §9) the contract applies to **every channel alike**.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

__all__ = ["BoundarySpec", "MixedBoundary", "PERIODIC", "NEUMANN0",
           "dirichlet", "mixed", "as_boundary", "axes_periodic", "pad_cube"]

_KINDS = ("periodic", "dirichlet", "neumann0")


@dataclass(frozen=True)
class BoundarySpec:
    """The boundary-condition contract of one stencil run.

    kind:  "periodic" | "dirichlet" | "neumann0"
    value: the fixed ghost value for dirichlet (ignored otherwise)

    ``clamped`` is the property every consumer branches on: clamped runs
    use the non-wrapping neighbour tables, refresh ghost layers per
    substep, and skip the wrapping ppermute links of the exchange.
    ``axes`` is the per-axis view shared with :class:`MixedBoundary`:
    a uniform contract is the same spec on all three axes.
    """
    kind: str = "periodic"
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown boundary kind {self.kind!r}; known: {_KINDS}")

    @property
    def clamped(self) -> bool:
        return self.kind != "periodic"

    @property
    def axes(self) -> tuple["BoundarySpec", "BoundarySpec", "BoundarySpec"]:
        return (self, self, self)


PERIODIC = BoundarySpec("periodic")
NEUMANN0 = BoundarySpec("neumann0")


@dataclass(frozen=True)
class MixedBoundary:
    """Per-axis boundary contract: one :class:`BoundarySpec` per grid axis.

    ``k``/``i``/``j`` follow the cube's axis order (the same order the
    exchange rings and ``apply_window_bc`` traverse). Frozen + hashable
    like :class:`BoundarySpec`, so it rides jit static arguments; the
    duck-typed ``kind``/``clamped``/``axes`` surface lets every existing
    ``bc`` knob accept a mixed contract unchanged. Build with
    :func:`mixed`, which collapses a uniform triple back to the plain
    spec (keeping cache keys canonical).
    """
    k: BoundarySpec = PERIODIC
    i: BoundarySpec = PERIODIC
    j: BoundarySpec = PERIODIC

    def __post_init__(self):
        for ax in (self.k, self.i, self.j):
            if not isinstance(ax, BoundarySpec):
                raise ValueError(
                    f"MixedBoundary axes must be BoundarySpec, got {ax!r}")

    @property
    def kind(self) -> str:
        return "mixed"

    @property
    def clamped(self) -> bool:
        return any(ax.clamped for ax in self.axes)

    @property
    def axes(self) -> tuple[BoundarySpec, BoundarySpec, BoundarySpec]:
        return (self.k, self.i, self.j)


def dirichlet(value: float = 0.0) -> BoundarySpec:
    """Fixed-value boundary: ghost sites hold ``value`` at every step."""
    return BoundarySpec("dirichlet", float(value))


def mixed(k: "BoundarySpec | str" = PERIODIC,
          i: "BoundarySpec | str" = PERIODIC,
          j: "BoundarySpec | str" = PERIODIC):
    """Per-axis contract, e.g. ``mixed(k="neumann0")`` for a clamped-k slab.

    Coerces kind strings per axis and collapses a uniform triple to the
    plain :class:`BoundarySpec` so ``mixed(k=bc, i=bc, j=bc) == bc``
    (one canonical cache key per contract).
    """
    k, i, j = as_boundary(k), as_boundary(i), as_boundary(j)
    if k == i == j:
        return k
    return MixedBoundary(k, i, j)


def as_boundary(bc: "BoundarySpec | MixedBoundary | str"):
    """Coerce a registry-style string ("periodic" | "neumann0" |
    "dirichlet", the latter with value 0.0) to a :class:`BoundarySpec`;
    :class:`MixedBoundary` passes through unchanged."""
    if isinstance(bc, (BoundarySpec, MixedBoundary)):
        return bc
    return BoundarySpec(bc)


def axes_periodic(bc) -> tuple[bool, bool, bool]:
    """Per-axis wrap flags — the neighbour-table / exchange-ring view."""
    return tuple(not ax.clamped for ax in as_boundary(bc).axes)


def _pad_axis(cube: jnp.ndarray, axis: int, g: int,
              bc: BoundarySpec) -> jnp.ndarray:
    pad = [(0, 0)] * cube.ndim
    pad[axis] = (g, g)
    if bc.kind == "periodic":
        return jnp.pad(cube, pad, mode="wrap")
    if bc.kind == "dirichlet":
        return jnp.pad(cube, pad, constant_values=bc.value)
    return jnp.pad(cube, pad, mode="edge")


def pad_cube(cube: jnp.ndarray, g: int, bc) -> jnp.ndarray:
    """Ghost-extend an (M,M,M) cube by ``g`` per side under ``bc``.

    The oracle-side realisation of the contract (kernels/ref.py): wrap
    for periodic, constant fill for dirichlet, edge replication for
    neumann0. The corner semantics (per-axis sequential application in
    k, i, j order) match ``apply_window_bc`` exactly — np.pad applies
    axes in order, and a :class:`MixedBoundary` pads each axis under its
    own spec in that same order.
    """
    bc = as_boundary(bc)
    axes = bc.axes
    if axes[0] == axes[1] == axes[2]:  # uniform contract: one fused pad
        a = axes[0]
        if a.kind == "periodic":
            return jnp.pad(cube, g, mode="wrap")
        if a.kind == "dirichlet":
            return jnp.pad(cube, g, constant_values=a.value)
        return jnp.pad(cube, g, mode="edge")
    out = cube
    for ax in range(3):
        out = _pad_axis(out, ax - 3, g, axes[ax])
    return out
