"""Physical boundary conditions for the stencil pipelines (DESIGN.md §8).

The paper's experiments — and the SEM locality study (arXiv:2104.08416)
it builds on — run on *physical* domains whose edges do not wrap. This
module is the one definition of that contract, shared by every pipeline
form (repack, resident, fused, distributed) and their jnp oracles:

- ``periodic``         — wrap at the domain edge (the torus default);
- ``dirichlet(value)`` — ghost sites hold a fixed value at all times;
- ``neumann0``         — zero normal gradient: ghost sites replicate the
  nearest in-domain plane (clamp-copy, ``jnp.pad(mode="edge")``).

A :class:`BoundarySpec` is frozen and hashable so it can ride jit static
arguments and cache keys exactly like an ``OrderingSpec``. Everything
downstream — the clamped neighbour tables (core/neighbors.py), the
in-window ghost refresh (kernels/rules.apply_window_bc), the mesh-edge
shell fill (stencil/halo.exchange_shell) and the exchange-surface
accounting (stencil/pipeline.py) — keys off the one ``kind`` string
defined here.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

__all__ = ["BoundarySpec", "PERIODIC", "NEUMANN0", "dirichlet",
           "as_boundary", "pad_cube"]

_KINDS = ("periodic", "dirichlet", "neumann0")


@dataclass(frozen=True)
class BoundarySpec:
    """The boundary-condition contract of one stencil run.

    kind:  "periodic" | "dirichlet" | "neumann0"
    value: the fixed ghost value for dirichlet (ignored otherwise)

    ``clamped`` is the property every consumer branches on: clamped runs
    use the non-wrapping neighbour tables, refresh ghost layers per
    substep, and skip the wrapping ppermute links of the exchange.
    """
    kind: str = "periodic"
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown boundary kind {self.kind!r}; known: {_KINDS}")

    @property
    def clamped(self) -> bool:
        return self.kind != "periodic"


PERIODIC = BoundarySpec("periodic")
NEUMANN0 = BoundarySpec("neumann0")


def dirichlet(value: float = 0.0) -> BoundarySpec:
    """Fixed-value boundary: ghost sites hold ``value`` at every step."""
    return BoundarySpec("dirichlet", float(value))


def as_boundary(bc: "BoundarySpec | str") -> BoundarySpec:
    """Coerce a registry-style string ("periodic" | "neumann0" |
    "dirichlet", the latter with value 0.0) to a :class:`BoundarySpec`."""
    if isinstance(bc, BoundarySpec):
        return bc
    return BoundarySpec(bc)


def pad_cube(cube: jnp.ndarray, g: int, bc: "BoundarySpec | str") -> jnp.ndarray:
    """Ghost-extend an (M,M,M) cube by ``g`` per side under ``bc``.

    The oracle-side realisation of the contract (kernels/ref.py): wrap
    for periodic, constant fill for dirichlet, edge replication for
    neumann0. The corner semantics (per-axis sequential replication)
    match ``apply_window_bc`` exactly — np.pad applies axes in order.
    """
    bc = as_boundary(bc)
    if bc.kind == "periodic":
        return jnp.pad(cube, g, mode="wrap")
    if bc.kind == "dirichlet":
        return jnp.pad(cube, g, constant_values=bc.value)
    return jnp.pad(cube, g, mode="edge")
