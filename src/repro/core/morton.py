"""3D/2D Morton (Z-order) encoding via dilated integers.

Vectorised numpy implementation of the bit-interleaving described in the
paper §2.1 (a 3D extension of Raman & Wise's dilated-integer technique).

Conventions follow the paper: an array location is ``(k, i, j)`` where ``j``
is the column (fastest-varying in row-major), ``i`` the row, ``k`` the slab.
The Morton index at full depth interleaves bits as ``... k_b i_b j_b`` with
``j`` in the least-significant position, so that Morton order of a
``2x2x2`` block visits it in row-major order — matching Fig. 1.

Level-``r`` Morton ordering (paper Fig. 2): the upper ``r`` bits of each of
``k,i,j`` are interleaved to form the top ``3r`` bits; the lower ``m-r``
bits of ``k``, then ``i``, then ``j`` follow — i.e. Morton between
``2^{m-r}``-cubes, row-major within.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "dilate3",
    "undilate3",
    "dilate2",
    "undilate2",
    "morton_encode3",
    "morton_decode3",
    "morton_encode2",
    "morton_decode2",
    "morton_encode3_level",
    "morton_decode3_level",
]

_U = np.uint64


def dilate3(x: np.ndarray) -> np.ndarray:
    """Spread the low 21 bits of ``x``: bit b -> bit 3b (dilated integer)."""
    x = np.asarray(x).astype(_U)  # astype copies: never mutate caller
    x &= _U(0x1FFFFF)  # 21 bits
    x = (x | (x << _U(32))) & _U(0x1F00000000FFFF)
    x = (x | (x << _U(16))) & _U(0x1F0000FF0000FF)
    x = (x | (x << _U(8))) & _U(0x100F00F00F00F00F)
    x = (x | (x << _U(4))) & _U(0x10C30C30C30C30C3)
    x = (x | (x << _U(2))) & _U(0x1249249249249249)
    return x


def undilate3(x: np.ndarray) -> np.ndarray:
    """Inverse of :func:`dilate3` (keeps every 3rd bit)."""
    x = np.asarray(x).astype(_U)  # astype copies: never mutate caller
    x &= _U(0x1249249249249249)
    x = (x | (x >> _U(2))) & _U(0x10C30C30C30C30C3)
    x = (x | (x >> _U(4))) & _U(0x100F00F00F00F00F)
    x = (x | (x >> _U(8))) & _U(0x1F0000FF0000FF)
    x = (x | (x >> _U(16))) & _U(0x1F00000000FFFF)
    x = (x | (x >> _U(32))) & _U(0x1FFFFF)
    return x


def dilate2(x: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of ``x``: bit b -> bit 2b."""
    x = np.asarray(x).astype(_U)  # astype copies: never mutate caller
    x &= _U(0xFFFFFFFF)
    x = (x | (x << _U(16))) & _U(0x0000FFFF0000FFFF)
    x = (x | (x << _U(8))) & _U(0x00FF00FF00FF00FF)
    x = (x | (x << _U(4))) & _U(0x0F0F0F0F0F0F0F0F)
    x = (x | (x << _U(2))) & _U(0x3333333333333333)
    x = (x | (x << _U(1))) & _U(0x5555555555555555)
    return x


def undilate2(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x).astype(_U)  # astype copies: never mutate caller
    x &= _U(0x5555555555555555)
    x = (x | (x >> _U(1))) & _U(0x3333333333333333)
    x = (x | (x >> _U(2))) & _U(0x0F0F0F0F0F0F0F0F)
    x = (x | (x >> _U(4))) & _U(0x00FF00FF00FF00FF)
    x = (x | (x >> _U(8))) & _U(0x0000FFFF0000FFFF)
    x = (x | (x >> _U(16))) & _U(0xFFFFFFFF)
    return x


def morton_encode3(k, i, j) -> np.ndarray:
    """Full-depth 3D Morton index of location ``(k,i,j)`` (j least significant)."""
    return (dilate3(k) << _U(2)) | (dilate3(i) << _U(1)) | dilate3(j)


def morton_decode3(idx) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    idx = np.asarray(idx, dtype=_U)
    return (
        undilate3(idx >> _U(2)),
        undilate3(idx >> _U(1)),
        undilate3(idx),
    )


def morton_encode2(i, j) -> np.ndarray:
    return (dilate2(i) << _U(1)) | dilate2(j)


def morton_decode2(idx) -> tuple[np.ndarray, np.ndarray]:
    idx = np.asarray(idx, dtype=_U)
    return undilate2(idx >> _U(1)), undilate2(idx)


def morton_encode3_level(k, i, j, m: int, r: int) -> np.ndarray:
    """Level-``r`` Morton index for an ``M^3`` array, ``M = 2^m`` (paper Fig. 2).

    The top ``r`` bits of each coordinate are interleaved (Morton between
    ``2^{m-r}``-cubes); the low ``m-r`` bits of ``k``, ``i``, ``j`` follow in
    row-major order within the cube. ``r = m`` is full-depth Morton,
    ``r = 0`` is plain row-major.
    """
    if not (0 <= r <= m):
        raise ValueError(f"need 0 <= r <= m, got r={r}, m={m}")
    k = np.asarray(k, dtype=_U)
    i = np.asarray(i, dtype=_U)
    j = np.asarray(j, dtype=_U)
    low = m - r
    hi = morton_encode3(k >> _U(low), i >> _U(low), j >> _U(low))
    mask = _U((1 << low) - 1)
    return (
        (hi << _U(3 * low))
        | ((k & mask) << _U(2 * low))
        | ((i & mask) << _U(low))
        | (j & mask)
    )


def morton_decode3_level(idx, m: int, r: int):
    """Inverse of :func:`morton_encode3_level`."""
    if not (0 <= r <= m):
        raise ValueError(f"need 0 <= r <= m, got r={r}, m={m}")
    idx = np.asarray(idx, dtype=_U)
    low = m - r
    mask = _U((1 << low) - 1)
    j_lo = idx & mask
    i_lo = (idx >> _U(low)) & mask
    k_lo = (idx >> _U(2 * low)) & mask
    k_hi, i_hi, j_hi = morton_decode3(idx >> _U(3 * low))
    return (
        (k_hi << _U(low)) | k_lo,
        (i_hi << _U(low)) | i_lo,
        (j_hi << _U(low)) | j_lo,
    )
