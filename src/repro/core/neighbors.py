"""Precomputed SFC block-neighbour tables (DESIGN.md §3).

The resident-block pipeline (stencil/pipeline.py, kernels/stencil3d.py)
keeps the cube as an ``(nb, T, T, T)`` curve-ordered block store for the
whole multi-step loop — the paper's "reorder once, iterate many times"
discipline.  Halo assembly then needs, for the block at *path position*
``t``, the path positions of its 26 grid neighbours.  This module builds
those tables once per ``(ordering, nt)`` pair, as int32 (they ride the
TPU scalar-prefetch channel), with periodic and clamped variants.

Offsets are enumerated in row-major order of ``(dk+1, di+1, dj+1)`` so
that column ``(a·9 + b·3 + c)`` of a full table is the neighbour at
offset ``(a-1, b-1, c-1)`` — the same order the kernel assembles its
``(T+2g)³`` VMEM window in, and column :data:`SELF_COL` (= 13) is the
block itself.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from .layout import block_order, device_constant
from .orderings import OrderingSpec

__all__ = [
    "OFFSETS_FULL", "OFFSETS_FACE", "FACE_COLS", "SELF_COL",
    "block_kind_of", "neighbor_table", "neighbor_table_device", "ring_perms",
    "boundary_face_table", "boundary_face_table_device",
    "shell_block_count", "shell_block_index", "extended_neighbor_table",
    "extended_neighbor_table_device",
]

OFFSETS_FULL = tuple((a - 1, b - 1, c - 1)
                     for a in range(3) for b in range(3) for c in range(3))
SELF_COL = OFFSETS_FULL.index((0, 0, 0))  # 13

# face (von-Neumann) neighbours in [k-, k+, i-, i+, j-, j+] order
OFFSETS_FACE = ((-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0),
                (0, 0, -1), (0, 0, 1))
FACE_COLS = tuple(OFFSETS_FULL.index(o) for o in OFFSETS_FACE)


def block_kind_of(spec: OrderingSpec | str) -> str:
    """Block-granularity curve induced by an ordering.

    Morton and Hilbert are hierarchical: the order in which the
    element-level curve visits T³ tiles *is* the same curve on the
    nt³ block grid (the top 3·log2(nt) bits of the index), so the
    element ordering's kind carries over directly. A hybrid ordering's
    block order is its ``outer`` curve; row/column-major likewise
    induce themselves.
    """
    if isinstance(spec, str):
        return spec
    if spec.kind == "hybrid":
        return spec.outer
    return spec.kind


def _periodic_axes(periodic) -> tuple[bool, bool, bool]:
    """Normalise the ``periodic`` knob: a bool applies to all three axes,
    a 3-sequence gives the per-axis wrap flags (mixed boundary contracts,
    core.boundary.axes_periodic — DESIGN.md §8)."""
    if isinstance(periodic, bool):
        return (periodic,) * 3
    per = tuple(bool(p) for p in periodic)
    if len(per) != 3:
        raise ValueError(f"periodic must be a bool or 3 flags, got {periodic!r}")
    return per


def neighbor_table(spec: OrderingSpec | str, nt: int, *,
                   connectivity: str = "full",
                   periodic=True) -> np.ndarray:
    """Path-position → neighbour path-positions, int32, read-only.

    spec:         OrderingSpec or block-kind string (see block_kind_of)
    nt:           blocks per cube edge (power of 2)
    connectivity: "full" → (nt³, 27) table over OFFSETS_FULL;
                  "face" → (nt³, 6) table over OFFSETS_FACE
    periodic:     wrap at the grid boundary; otherwise clamp to the edge
                  block (note: block-level clamping replicates *blocks*,
                  not elements — it matches jnp.pad(mode="edge") only for
                  the face-adjacent halo layer, which is what the
                  distributed exchange consumes). A per-axis 3-tuple of
                  flags realises mixed contracts (clamped k, periodic
                  i/j — core.boundary.MixedBoundary): each axis wraps or
                  clamps independently.

    ``table[t, o]`` is the path position of the block at offset
    ``OFFSETS[o]`` from the block the curve visits at position ``t``.
    """
    # normalise before the cache: lists/tuples of flags both hit one key
    # (and bad inputs raise the friendly ValueError, not lru_cache's)
    return _neighbor_table_cached(spec, nt, connectivity,
                                  _periodic_axes(periodic))


@functools.lru_cache(maxsize=128)
def _neighbor_table_cached(spec: OrderingSpec | str, nt: int,
                           connectivity: str,
                           periodic: tuple[bool, bool, bool]) -> np.ndarray:
    if connectivity not in ("full", "face"):
        raise ValueError(f"unknown connectivity {connectivity!r}")
    kind = block_kind_of(spec)
    full = _full_table(kind, nt, periodic)
    if connectivity == "face":
        face = full[:, FACE_COLS]
        face.setflags(write=False)
        return face
    return full


@functools.lru_cache(maxsize=128)
def _full_table(kind: str, nt: int,
                periodic: tuple[bool, bool, bool]) -> np.ndarray:
    bo = block_order(kind, nt)  # (nb, 3): path pos -> block coords
    nb = nt ** 3
    lin = bo[:, 0] * nt * nt + bo[:, 1] * nt + bo[:, 2]
    lin_to_path = np.empty(nb, dtype=np.int64)
    lin_to_path[lin] = np.arange(nb)
    offs = np.asarray(OFFSETS_FULL, dtype=np.int64)  # (27, 3)
    co = bo[:, None, :] + offs[None, :, :]           # (nb, 27, 3)
    for ax in range(3):
        if periodic[ax]:
            co[..., ax] %= nt
        else:
            np.clip(co[..., ax], 0, nt - 1, out=co[..., ax])
    tab = lin_to_path[(co[..., 0] * nt + co[..., 1]) * nt + co[..., 2]]
    tab = tab.astype(np.int32)
    tab.setflags(write=False)
    return tab


def neighbor_table_device(spec: OrderingSpec | str, nt: int, *,
                          connectivity: str = "full",
                          periodic=True) -> jnp.ndarray:
    """Cached device-resident copy (the kernel's scalar-prefetch operand)."""
    kind = block_kind_of(spec)
    per = _periodic_axes(periodic)
    return device_constant(
        ("nbrtab", kind, nt, connectivity, per),
        lambda: neighbor_table(kind, nt, connectivity=connectivity,
                               periodic=per))


def shell_block_count(nt: int) -> int:
    """Blocks in the one-block-thick shell around an nt³ core grid."""
    return (nt + 2) ** 3 - nt ** 3


@functools.lru_cache(maxsize=128)
def shell_block_index(nt: int) -> np.ndarray:
    """Extended-grid block coords -> shell enumeration id (core = -1).

    The distributed pipeline (stencil/halo.py) appends the exchanged halo
    as *shell blocks* after the nt³ core store: a block at extended
    coords ``(bk, bi, bj) ∈ [-1, nt]³`` outside the core gets id
    ``shell_block_index(nt)[bk+1, bi+1, bj+1]`` (row-major enumeration of
    the shell), and lives at store row ``nt³ + id``. Core coords map to
    -1 — core rows are addressed by the block curve's own path positions.
    """
    e = nt + 2
    kk, ii, jj = np.meshgrid(*(np.arange(e),) * 3, indexing="ij")
    core = ((kk >= 1) & (kk <= nt) & (ii >= 1) & (ii <= nt)
            & (jj >= 1) & (jj <= nt))
    idx = np.full((e, e, e), -1, dtype=np.int32)
    idx[~core] = np.arange(shell_block_count(nt), dtype=np.int32)
    idx.setflags(write=False)
    return idx


@functools.lru_cache(maxsize=128)
def extended_neighbor_table(spec: OrderingSpec | str, nt: int) -> np.ndarray:
    """(nt³, 27) int32 neighbour table over the core+shell extended store.

    Row ``t`` (the core block the curve visits at path position ``t``)
    holds, per OFFSETS_FULL column, either the path position of a core
    neighbour or ``nt³ + shell_id`` of the shell block that carries the
    exchanged halo in that direction — the scalar-prefetch operand of the
    distributed fused step (stencil/halo.shard_substeps). Column
    :data:`SELF_COL` is ``t`` itself, as in :func:`neighbor_table`.
    """
    kind = block_kind_of(spec)
    bo = block_order(kind, nt)  # (nb, 3): path pos -> block coords
    nb = nt ** 3
    lin = bo[:, 0] * nt * nt + bo[:, 1] * nt + bo[:, 2]
    lin_to_path = np.empty(nb, dtype=np.int64)
    lin_to_path[lin] = np.arange(nb)
    offs = np.asarray(OFFSETS_FULL, dtype=np.int64)  # (27, 3)
    co = bo[:, None, :] + offs[None, :, :]           # (nb, 27, 3)
    inside = ((co >= 0) & (co < nt)).all(axis=-1)
    coc = np.clip(co, 0, nt - 1)
    core_ids = lin_to_path[(coc[..., 0] * nt + coc[..., 1]) * nt + coc[..., 2]]
    shell_ids = shell_block_index(nt)[co[..., 0] + 1, co[..., 1] + 1,
                                      co[..., 2] + 1]
    tab = np.where(inside, core_ids, nb + shell_ids).astype(np.int32)
    tab.setflags(write=False)
    return tab


def extended_neighbor_table_device(spec: OrderingSpec | str,
                                   nt: int) -> jnp.ndarray:
    """Cached device-resident copy of :func:`extended_neighbor_table`."""
    kind = block_kind_of(spec)
    return device_constant(("extnbrtab", kind, nt),
                           lambda: extended_neighbor_table(kind, nt))


def ring_perms(n: int, periodic: bool = True
               ) -> tuple[list[tuple[int, int]], list[tuple[int, int]]]:
    """(forward, backward) ppermute partner lists for a ring of n devices.

    The 1D special case of the face tables — device ``i``'s +axis
    neighbour is ``i+1 mod n`` — kept here so stencil/halo.py's exchange
    and the block tables share one source of neighbour conventions.
    (Direct formula: device meshes need not be powers of 2.)

    ``periodic=False`` is the clamped-boundary ring: the wrapping pairs
    ``(n-1, 0)`` / ``(0, n-1)`` are simply absent, so *no bytes move on
    the wrap link* — devices with no source receive zeros (``ppermute``
    semantics) and stencil/halo.exchange_shell substitutes boundary
    values there instead.
    """
    if not periodic:
        return ([(i, i + 1) for i in range(n - 1)],
                [(i, i - 1) for i in range(1, n)])
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


@functools.lru_cache(maxsize=128)
def boundary_face_table(spec: OrderingSpec | str, nt: int) -> np.ndarray:
    """(nb, 6) int32 flags: which faces of each block lie on the domain edge.

    Columns follow :data:`OFFSETS_FACE` order — ``[k-, k+, i-, i+, j-, j+]``
    — so column ``2·axis + side`` matches the face the fused kernel's
    ghost refresh (kernels/rules.apply_window_bc) masks. Row ``t`` is the
    block the curve visits at path position ``t``, same indexing as
    :func:`neighbor_table`. On a clamped run the resident pipeline feeds
    this table straight to the kernel; the distributed pipeline first
    AND-masks it with the shard's mesh position (only mesh-edge shards
    own global domain faces — stencil/halo.shard_substeps).
    """
    kind = block_kind_of(spec)
    bo = block_order(kind, nt)  # (nb, 3): path pos -> block coords
    cols = []
    for ax in range(3):
        cols += [bo[:, ax] == 0, bo[:, ax] == nt - 1]
    tab = np.stack(cols, axis=1).astype(np.int32)
    tab.setflags(write=False)
    return tab


def boundary_face_table_device(spec: OrderingSpec | str, nt: int) -> jnp.ndarray:
    """Cached device-resident copy of :func:`boundary_face_table`."""
    kind = block_kind_of(spec)
    return device_constant(("bndtab", kind, nt),
                           lambda: boundary_face_table(kind, nt))
