"""Data-ordering specifications and permutation builders (paper §2).

An ordering ``O`` of an ``M×M×M`` cube is a bijection between row-major
indices and *path* positions.  Following the paper:

- ``p(k,i,j)`` — position in the ordering of array location (k,i,j);
  materialised as ``rmo_to_path`` (array of length M³ indexed by row-major
  index).
- ``q(r)``    — row-major index of path position r; materialised as
  ``path_to_rmo`` (the inverse permutation).

Supported orderings:

- ``row_major``           — the baseline.
- ``column_major``        — for completeness (paper compares row/column).
- ``morton`` (level r)    — paper §2.1; ``level=None`` means full depth
                            (2×2×2 blocks, r = m), otherwise Morton between
                            ``2^{m-r}``-cubes, row-major inside (Fig. 2).
- ``hilbert``             — paper §2.2, full depth.
- ``hybrid``              — paper §2.3: ``outer`` ordering between T³ tiles,
                            ``inner`` ordering within each tile.

Permutations are cached (they are pure functions of (spec, M)).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from .hilbert import hilbert_encode, hilbert_encode3
from .morton import morton_encode2, morton_encode3, morton_encode3_level

__all__ = ["OrderingSpec", "ROW_MAJOR", "COLUMN_MAJOR", "MORTON", "HILBERT",
           "rmo_to_path", "path_to_rmo", "path_index_2d", "block_index_3d",
           "ordering_from_name"]


@dataclass(frozen=True)
class OrderingSpec:
    kind: str  # row_major | column_major | morton | hilbert | hybrid
    level: int | None = None  # morton recursion depth r (None = full)
    tile: int | None = None  # hybrid tile edge T
    outer: str | None = None  # hybrid: ordering between tiles
    inner: str | None = None  # hybrid: ordering within tiles

    def __post_init__(self):
        kinds = {"row_major", "column_major", "morton", "hilbert", "hybrid"}
        if self.kind not in kinds:
            raise ValueError(f"unknown ordering kind {self.kind!r}")
        if self.kind == "hybrid":
            if self.tile is None or self.outer is None or self.inner is None:
                raise ValueError("hybrid ordering needs tile, outer, inner")

    @property
    def name(self) -> str:
        if self.kind == "morton" and self.level is not None:
            return f"morton_r{self.level}"
        if self.kind == "hybrid":
            return f"hybrid_{self.outer}_{self.inner}_T{self.tile}"
        return self.kind


ROW_MAJOR = OrderingSpec("row_major")
COLUMN_MAJOR = OrderingSpec("column_major")
MORTON = OrderingSpec("morton")
HILBERT = OrderingSpec("hilbert")


def ordering_from_name(name: str) -> OrderingSpec:
    """Parse a CLI-friendly ordering name."""
    if name in ("row_major", "rm"):
        return ROW_MAJOR
    if name in ("column_major", "cm"):
        return COLUMN_MAJOR
    if name == "morton":
        return MORTON
    if name == "hilbert":
        return HILBERT
    if name.startswith("morton_r"):
        return OrderingSpec("morton", level=int(name[len("morton_r"):]))
    if name.startswith("hybrid_"):
        _, outer, inner, t = name.split("_")
        return OrderingSpec("hybrid", tile=int(t[1:]), outer=outer, inner=inner)
    raise ValueError(f"unknown ordering {name!r}")


def _check_pow2(M: int) -> int:
    m = int(M).bit_length() - 1
    if (1 << m) != M:
        raise ValueError(f"M must be a power of 2, got {M}")
    return m


def _check_int32(n: int) -> None:
    """Permutations are int32 (DESIGN.md §2): half the gather-index traffic
    and half the scalar-prefetch bytes of int64. Fine while indices fit."""
    if n >= 2 ** 31:
        raise ValueError(f"index space {n} overflows int32 permutations; "
                         "int32 is required for the TPU gather/prefetch path")


def _flat_index(kind: str, k, i, j, M: int) -> np.ndarray:
    """Path index of each (k,i,j) under a *simple* (non-hybrid) ordering."""
    m = _check_pow2(M)
    k = np.asarray(k, dtype=np.uint64)
    i = np.asarray(i, dtype=np.uint64)
    j = np.asarray(j, dtype=np.uint64)
    if M == 1:  # single-cell grid: every ordering is trivial (and the
        if kind not in ("row_major", "column_major", "morton", "hilbert"):
            raise ValueError(f"unknown simple ordering {kind!r}")
        return k * i * j  # hilbert codec rejects bit-width 0)
    MM = np.uint64(M)
    if kind == "row_major":
        return (k * MM + i) * MM + j
    if kind == "column_major":
        return (j * MM + i) * MM + k
    if kind == "morton":
        return morton_encode3(k, i, j)
    if kind == "hilbert":
        return hilbert_encode3(k, i, j, m)
    raise ValueError(f"unknown simple ordering {kind!r}")


def block_index_3d(kind: str, k, i, j, n: int) -> np.ndarray:
    """Curve index of 3-D grid coordinates under a *simple* ordering.

    The public form of the block-grid path index: serve/roi.py maps the
    block box of an ROI through this to get curve indices over the nt³
    block grid (DESIGN.md §11), the same function the block store's
    permutation is built from — so a range of these indices IS a
    contiguous run of blocks in HBM. ``kind`` is one of
    row_major | column_major | morton | hilbert; ``n`` the grid edge
    (power of 2). Accepts scalars or arrays; returns int64.
    """
    return _flat_index(kind, k, i, j, n).astype(np.int64)


@functools.lru_cache(maxsize=128)
def rmo_to_path(spec: OrderingSpec, M: int) -> np.ndarray:
    """p: row-major index -> path position. int32 array of length M³."""
    m = _check_pow2(M)
    _check_int32(M ** 3)
    kk, ii, jj = np.meshgrid(
        np.arange(M, dtype=np.uint64),
        np.arange(M, dtype=np.uint64),
        np.arange(M, dtype=np.uint64),
        indexing="ij",
    )
    kk, ii, jj = kk.ravel(), ii.ravel(), jj.ravel()
    if spec.kind in ("row_major", "column_major", "hilbert"):
        p = _flat_index(spec.kind, kk, ii, jj, M)
    elif spec.kind == "morton":
        r = m if spec.level is None else spec.level
        p = morton_encode3_level(kk, ii, jj, m, r)
    elif spec.kind == "hybrid":
        T = spec.tile
        if T is None or M % T:
            raise ValueError(f"tile {T} must divide M={M}")
        nt = M // T
        outer_idx = _flat_index(spec.outer, kk // T, ii // T, jj // T, nt)
        inner_idx = _flat_index(spec.inner, kk % T, ii % T, jj % T, T)
        p = outer_idx * np.uint64(T * T * T) + inner_idx
    else:  # pragma: no cover
        raise ValueError(spec.kind)
    p = p.astype(np.int32)
    p.setflags(write=False)
    return p


@functools.lru_cache(maxsize=128)
def path_to_rmo(spec: OrderingSpec, M: int) -> np.ndarray:
    """q: path position -> row-major index (inverse permutation of p)."""
    p = rmo_to_path(spec, M)
    q = np.empty_like(p)
    q[p] = np.arange(p.size, dtype=np.int32)
    q.setflags(write=False)
    return q


@functools.lru_cache(maxsize=64)
def path_index_2d(kind: str, n: int) -> np.ndarray:
    """2D path index grid (n×n, n=2^b) for morton/hilbert/row_major.

    Used by the flash-attention kernel to traverse the (q-block, kv-block)
    grid along a space-filling curve (DESIGN.md §5, applicability level 2).
    Returns an int32 (n*n,) array: sequence of row-major block ids in path
    order.
    """
    b = _check_pow2(n)
    ii, jj = np.meshgrid(np.arange(n, dtype=np.uint64),
                         np.arange(n, dtype=np.uint64), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    if kind == "row_major":
        p = ii * np.uint64(n) + jj
    elif kind == "morton":
        p = morton_encode2(ii, jj)
    elif kind == "hilbert":
        p = hilbert_encode([ii, jj], b)
    else:
        raise ValueError(f"unknown 2D ordering {kind!r}")
    q = np.empty(n * n, dtype=np.int32)
    q[p.astype(np.int64)] = np.arange(n * n, dtype=np.int32)
    q.setflags(write=False)
    return q
