"""Memory-offset histograms and the paper's LRU cache model (Alg. 1).

Reproduces:
- ``h_O(x)`` — accumulated memory offsets over all interior stencils
  (paper §3.1, Figs 5–7).
- ``cacheModel`` — the fully-associative LRU miss counter with cache-line
  size ``b`` (items) and capacity ``c`` (lines), Alg. 1.
- The surface variant (§3.2): the border conditional negated / restricted
  to one of the six faces, modelling pack-buffer reads.

On TPU the same model is reused with VMEM-like parameters (a "line" is a
Pallas block, the "cache" is VMEM) — see DESIGN.md §2.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from .orderings import OrderingSpec, path_to_rmo, rmo_to_path

__all__ = [
    "stencil_offsets",
    "offset_histogram",
    "offset_summary",
    "simulate_lru",
    "cache_misses",
    "surface_cache_misses",
    "OffsetSummary",
]


def stencil_offsets(g: int) -> np.ndarray:
    """(2g+1)³ × 3 array of (dk,di,dj) stencil offsets, row-major order."""
    r = np.arange(-g, g + 1)
    dk, di, dj = np.meshgrid(r, r, r, indexing="ij")
    return np.stack([dk.ravel(), di.ravel(), dj.ravel()], axis=1)


def _path_grid(spec: OrderingSpec, M: int) -> np.ndarray:
    """(M,M,M) grid of path positions p(k,i,j)."""
    return rmo_to_path(spec, M).reshape(M, M, M)


def offset_histogram(spec: OrderingSpec, M: int, g: int):
    """h_O(x): counts of path-offset x over all interior stencil accesses.

    Returns (offsets, counts) with offsets sorted ascending. For row-major
    ordering this reproduces the closed form: (2g+1)³ distinct offsets each
    with count (M-2g)³ (paper §3.1 / Fig. 4).
    """
    pos = _path_grid(spec, M)
    interior = pos[g:M - g, g:M - g, g:M - g]
    offs: dict[int, int] = {}
    for dk, di, dj in stencil_offsets(g):
        nb = pos[g + dk:M - g + dk, g + di:M - g + di, g + dj:M - g + dj]
        x = (nb.astype(np.int64) - interior.astype(np.int64)).ravel()
        vals, cnts = np.unique(x, return_counts=True)
        for v, c in zip(vals.tolist(), cnts.tolist()):
            offs[v] = offs.get(v, 0) + c
    keys = np.array(sorted(offs), dtype=np.int64)
    return keys, np.array([offs[k] for k in keys.tolist()], dtype=np.int64)


@dataclass(frozen=True)
class OffsetSummary:
    ordering: str
    M: int
    g: int
    n_distinct: int            # distinct offsets with h_O(x) > 0
    mean_abs: float            # mean |x| weighted by h_O(x)
    p99_abs: float             # 99th percentile of |x|
    frac_within_line: float    # fraction of accesses with |x| < b_ref (64)


def offset_summary(spec: OrderingSpec, M: int, g: int, b_ref: int = 64) -> OffsetSummary:
    keys, cnts = offset_histogram(spec, M, g)
    a = np.abs(keys)
    w = cnts / cnts.sum()
    order = np.argsort(a)
    cw = np.cumsum(w[order])
    p99 = float(a[order][np.searchsorted(cw, 0.99)])
    return OffsetSummary(
        ordering=spec.name, M=M, g=g,
        n_distinct=int(len(keys)),
        mean_abs=float((a * w).sum()),
        p99_abs=p99,
        frac_within_line=float(w[a < b_ref].sum()),
    )


def simulate_lru(lines: np.ndarray, c: int) -> int:
    """Count misses of a fully-associative LRU cache of ``c`` lines.

    ``lines`` is the access sequence of cache-line ids.
    """
    cache: OrderedDict[int, None] = OrderedDict()
    misses = 0
    for ln in lines.tolist():
        if ln in cache:
            cache.move_to_end(ln)
        else:
            misses += 1
            cache[ln] = None
            if len(cache) > c:
                cache.popitem(last=False)
    return misses


def _access_lines(spec: OrderingSpec, M: int, g: int, b: int,
                  centers_rmo: np.ndarray) -> np.ndarray:
    """Cache-line id sequence for stencil sweeps over the given centers.

    ``centers_rmo`` is already in *path* (update) order; for each center the
    (2g+1)³ stencil locations are accessed in row-major offset order
    (Alg. 1 line 6), each mapped to its path address then line id.
    """
    p = rmo_to_path(spec, M)
    M2 = M * M
    k = centers_rmo // M2
    i = (centers_rmo // M) % M
    j = centers_rmo % M
    offs = stencil_offsets(g)
    # (n_centers, n_offsets) neighbour row-major indices
    nk = k[:, None] + offs[None, :, 0]
    ni = i[:, None] + offs[None, :, 1]
    nj = j[:, None] + offs[None, :, 2]
    nrmo = (nk * M + ni) * M + nj
    lines = p[nrmo.ravel()] // b
    return lines


def cache_misses(spec: OrderingSpec, M: int, g: int, b: int, c: int) -> int:
    """Alg. 1: LRU misses for a full interior sweep in path order."""
    q = path_to_rmo(spec, M)
    M2 = M * M
    k = q // M2
    i = (q // M) % M
    j = q % M
    interior = (k >= g) & (k < M - g) & (i >= g) & (i < M - g) & (j >= g) & (j < M - g)
    centers = q[interior]  # visits in path order, border excluded (line 5)
    lines = _access_lines(spec, M, g, b, centers)
    return simulate_lru(lines, c)


_FACES = ("k0", "k1", "i0", "i1", "j0", "j1")


def face_mask(face: str, M: int, g: int) -> np.ndarray:
    """Boolean (M³,) row-major mask of one of the six width-g faces.

    Face naming: ``k0`` = (0:g, :, :) — the paper's slab-row front surface
    pair is (j0,j1) in this notation? No: the paper names surfaces by the
    two axes that span them.  Mapping (paper → here):
      row-column  (rc) spanned by rows+cols   → k0/k1 (front/back slabs)
      column-slab (cs) spanned by cols+slabs  → i0/i1
      slab-row    (sr) spanned by slabs+rows  → j0/j1
    """
    if face not in _FACES:
        raise ValueError(f"face must be one of {_FACES}")
    idx = np.arange(M * M * M, dtype=np.int64)
    M2 = M * M
    k = idx // M2
    i = (idx // M) % M
    j = idx % M
    ax, side = face[0], face[1]
    coord = {"k": k, "i": i, "j": j}[ax]
    return (coord < g) if side == "0" else (coord >= M - g)


def surface_cache_misses(spec: OrderingSpec, M: int, g: int, b: int, c: int,
                         face: str, stencil: bool = False) -> int:
    """§3.2 variant: sweep only the points of one face, in path order.

    With ``stencil=False`` each visit touches just the face point (models
    reading the surface into a pack buffer); with ``stencil=True`` the full
    Alg.-1-negated behaviour (stencil accesses centred on border points).
    """
    q = path_to_rmo(spec, M)
    mask = face_mask(face, M, g)
    centers = q[mask[q]]  # face points in path order
    if stencil:
        # clip stencil to the array (border stencils reach outside otherwise)
        p = rmo_to_path(spec, M)
        M2 = M * M
        k = centers // M2
        i = (centers // M) % M
        j = centers % M
        offs = stencil_offsets(g)
        nk = np.clip(k[:, None] + offs[None, :, 0], 0, M - 1)
        ni = np.clip(i[:, None] + offs[None, :, 1], 0, M - 1)
        nj = np.clip(j[:, None] + offs[None, :, 2], 0, M - 1)
        lines = p[((nk * M + ni) * M + nj).ravel()] // b
    else:
        p = rmo_to_path(spec, M)
        lines = p[centers] // b
    return simulate_lru(lines, c)
