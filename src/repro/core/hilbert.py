"""2D/3D Hilbert curve encoding, vectorised.

The paper (§2.2) generates the 3D Hilbert ordering from a Lindenmayer
system. We use Skilling's transpose algorithm ("Programming the Hilbert
curve", AIP Conf. Proc. 707, 2004), which produces the same curve family
(bijective, unit-stride between consecutive path positions, starts at the
origin) and vectorises cleanly over numpy arrays. Orientation may differ
from a specific L-system realisation; locality statistics are identical
by symmetry. Bijectivity and the unit-neighbour property are enforced by
tests (tests/test_sfc_properties.py).

``b`` is bits per coordinate (M = 2**b); n=2 or 3 dimensions.
"""

from __future__ import annotations

import numpy as np

from .morton import dilate2, dilate3, undilate2, undilate3

__all__ = ["hilbert_encode", "hilbert_decode", "hilbert_encode3", "hilbert_decode3"]

_U = np.uint64


def _axes_to_transpose(coords: list[np.ndarray], b: int) -> list[np.ndarray]:
    """Skilling AxestoTranspose, vectorised. coords: list of n uint64 arrays."""
    n = len(coords)
    x = [c.astype(_U).copy() for c in coords]
    q = _U(1) << _U(b - 1)
    # Inverse undo excess work
    while q > _U(1):
        p = q - _U(1)
        for i in range(n):
            cond = (x[i] & q) != 0
            # if set: invert low bits of x[0]; else swap low bits of x[0], x[i]
            t = (x[0] ^ x[i]) & p
            x0_if = x[0] ^ p
            x0_else = x[0] ^ t
            xi_else = x[i] ^ t
            x[0] = np.where(cond, x0_if, x0_else)
            x[i] = np.where(cond, x[i], xi_else)
        q >>= _U(1)
    # Gray encode
    for i in range(1, n):
        x[i] = x[i] ^ x[i - 1]
    t = np.zeros_like(x[0])
    q = _U(1) << _U(b - 1)
    while q > _U(1):
        cond = (x[n - 1] & q) != 0
        t = np.where(cond, t ^ (q - _U(1)), t)
        q >>= _U(1)
    for i in range(n):
        x[i] = x[i] ^ t
    return x


def _transpose_to_axes(x: list[np.ndarray], b: int) -> list[np.ndarray]:
    """Skilling TransposetoAxes, vectorised (inverse of _axes_to_transpose)."""
    n = len(x)
    x = [c.astype(_U).copy() for c in x]
    big = _U(2) << _U(b - 1)
    # Gray decode by H ^ (H/2)
    t = x[n - 1] >> _U(1)
    for i in range(n - 1, 0, -1):
        x[i] = x[i] ^ x[i - 1]
    x[0] = x[0] ^ t
    # Undo excess work
    q = _U(2)
    while q != big:
        p = q - _U(1)
        for i in range(n - 1, -1, -1):
            cond = (x[i] & q) != 0
            t = (x[0] ^ x[i]) & p
            x0_if = x[0] ^ p
            x0_else = x[0] ^ t
            xi_else = x[i] ^ t
            x[0] = np.where(cond, x0_if, x0_else)
            x[i] = np.where(cond, x[i], xi_else)
        q <<= _U(1)
    return x


def hilbert_encode(coords, b: int) -> np.ndarray:
    """Hilbert index of ``coords`` (list/tuple of n arrays), b bits per axis.

    coords[0] is the most-significant axis (the paper's slab index k for 3D).
    """
    n = len(coords)
    xt = _axes_to_transpose([np.asarray(c) for c in coords], b)
    if n == 3:
        return (dilate3(xt[0]) << _U(2)) | (dilate3(xt[1]) << _U(1)) | dilate3(xt[2])
    if n == 2:
        return (dilate2(xt[0]) << _U(1)) | dilate2(xt[1])
    raise ValueError(f"unsupported ndim {n}")


def hilbert_decode(idx, n: int, b: int) -> list[np.ndarray]:
    """Inverse of :func:`hilbert_encode`: Hilbert index -> n coordinates."""
    idx = np.asarray(idx, dtype=_U)
    if n == 3:
        xt = [undilate3(idx >> _U(2)), undilate3(idx >> _U(1)), undilate3(idx)]
    elif n == 2:
        xt = [undilate2(idx >> _U(1)), undilate2(idx)]
    else:
        raise ValueError(f"unsupported ndim {n}")
    return _transpose_to_axes(xt, b)


def hilbert_encode3(k, i, j, m: int) -> np.ndarray:
    """3D Hilbert index of (k,i,j) in an ``2^m``-cube (paper convention)."""
    return hilbert_encode([k, i, j], m)


def hilbert_decode3(idx, m: int):
    k, i, j = hilbert_decode(idx, 3, m)
    return k, i, j
