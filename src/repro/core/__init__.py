"""Core: space-filling-curve orderings, cache model, layouts (the paper's contribution)."""

from .orderings import (  # noqa: F401
    OrderingSpec, ROW_MAJOR, COLUMN_MAJOR, MORTON, HILBERT,
    rmo_to_path, path_to_rmo, path_index_2d, ordering_from_name,
)
from .morton import (  # noqa: F401
    morton_encode3, morton_decode3, morton_encode2, morton_decode2,
    morton_encode3_level, morton_decode3_level,
)
from .hilbert import hilbert_encode3, hilbert_decode3, hilbert_encode, hilbert_decode  # noqa: F401
from .cache_model import (  # noqa: F401
    offset_histogram, offset_summary, cache_misses, surface_cache_misses,
    simulate_lru, stencil_offsets,
)
from .surfaces import (  # noqa: F401
    FACES, PAPER_SURFACE_NAMES, surface_path_indices, run_stats, surface_runs,
)
from .layout import (  # noqa: F401
    apply_ordering, undo_ordering, blockize, unblockize, blockize_with_halo,
    blockize_fields, unblockize_fields, block_order,
)
from .neighbors import (  # noqa: F401
    OFFSETS_FULL, OFFSETS_FACE, FACE_COLS, SELF_COL,
    block_kind_of, boundary_face_table, neighbor_table,
    neighbor_table_device, ring_perms,
)
from .boundary import (  # noqa: F401
    BoundarySpec, MixedBoundary, PERIODIC, NEUMANN0, dirichlet, mixed,
    as_boundary, axes_periodic, pad_cube,
)
