"""Surface (face) index lists for halo pack/unpack (paper §3.2, §4).

The paper packs each of the six width-``g`` faces of the cube into a
contiguous buffer using *precomputed lists of path indices* (one initial
traversal, memory cost 6gM² integers). This module builds those lists for
any ordering, plus run-length statistics that quantify how contiguous the
pack reads are — the structural quantity behind Figs 11/15: row-major
layouts read the sr faces at stride M² (runs of length 1) while SFC
layouts read every face in runs of whole curve blocks.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from .cache_model import face_mask
from .orderings import OrderingSpec, rmo_to_path

__all__ = ["FACES", "PAPER_SURFACE_NAMES", "surface_path_indices",
           "run_lengths", "RunStats", "run_stats", "surface_runs",
           "shell_slab_shapes", "shell_slab_positions"]

FACES = ("k0", "k1", "i0", "i1", "j0", "j1")

# paper's surface naming (Figs 11/15): rc = row-column, cs = column-slab,
# sr = slab-row; F/B = front/back.
PAPER_SURFACE_NAMES = {
    "k0": "rcF", "k1": "rcB",
    "i0": "csF", "i1": "csB",
    "j0": "srF", "j1": "srB",
}


@functools.lru_cache(maxsize=256)
def surface_path_indices(spec: OrderingSpec, M: int, g: int, face: str) -> np.ndarray:
    """Path indices (positions in the ordering) of one face, ascending.

    Ascending path order == the order in which the curve visits the face,
    which is the pack order used by the paper (p_t in §3.2). Length gM².
    """
    p = rmo_to_path(spec, M)
    idx = p[face_mask(face, M, g)]
    idx = np.sort(idx)
    idx.setflags(write=False)
    return idx


def run_lengths(sorted_idx: np.ndarray) -> np.ndarray:
    """Lengths of maximal runs of consecutive integers in a sorted array."""
    if sorted_idx.size == 0:
        return np.zeros(0, dtype=np.int64)
    breaks = np.flatnonzero(np.diff(sorted_idx) != 1)
    starts = np.concatenate([[0], breaks + 1])
    ends = np.concatenate([breaks + 1, [sorted_idx.size]])
    return (ends - starts).astype(np.int64)


@dataclass(frozen=True)
class RunStats:
    face: str
    paper_name: str
    n_elems: int
    n_runs: int
    mean_run: float
    min_run: int
    max_run: int


def run_stats(spec: OrderingSpec, M: int, g: int, face: str) -> RunStats:
    idx = surface_path_indices(spec, M, g, face)
    rl = run_lengths(idx)
    return RunStats(
        face=face, paper_name=PAPER_SURFACE_NAMES[face],
        n_elems=int(idx.size), n_runs=int(rl.size),
        mean_run=float(rl.mean()) if rl.size else 0.0,
        min_run=int(rl.min()) if rl.size else 0,
        max_run=int(rl.max()) if rl.size else 0,
    )


def shell_slab_shapes(M: int, h: int) -> tuple[tuple[int, int, int], ...]:
    """Canonical shapes of the six exchanged shell slabs, width ``h``.

    Order is (k-lo, k-hi, i-lo, i-hi, j-lo, j-hi) — the axis-sequential
    corner-correct exchange: the k slabs span the bare M² face, the i
    slabs the k-extended face, the j slabs the fully extended face. Their
    union is exactly the shell of the (M+2h)³ extended cube.
    """
    e = M + 2 * h
    return ((h, M, M), (h, M, M), (e, h, M), (e, h, M), (e, e, h), (e, e, h))


@functools.lru_cache(maxsize=128)
def shell_slab_positions(nt: int, T: int, h: int) -> np.ndarray:
    """Scatter positions of the six shell slabs into the shell block store.

    The distributed pipeline holds the exchanged halo as *shell blocks*
    appended after the core store (core/neighbors.shell_block_index):
    ``shell.ravel()[pos] = concat(slab.ravel() for six slabs)`` fills an
    ``(shell_block_count(nt), T, T, T)`` array so that the fused kernel's
    neighbour-slice addressing (kernels/stencil3d._piece_specs) reads the
    halo exactly where a periodic in-store neighbour would hold it: a
    low-side shell block carries its data in its *last* h-slab, a
    high-side one in its first. Slab order matches
    :func:`shell_slab_shapes`; h ≤ T.
    """
    from .neighbors import shell_block_index

    assert h <= T, (h, T)
    M = nt * T
    sid = shell_block_index(nt)

    def _axis(e):
        # extended-domain coord e ∈ [-h, M+h) -> (block coord, in-block offset)
        blk = np.where(e < 0, -1, np.where(e >= M, nt, e // T))
        off = np.where(e < 0, T + e, np.where(e >= M, e - M, e % T))
        return blk, off

    lo, hi = np.arange(-h, 0), np.arange(M, M + h)
    core, ext = np.arange(M), np.arange(-h, M + h)
    regions = ((lo, core, core), (hi, core, core),
               (ext, lo, core), (ext, hi, core),
               (ext, ext, lo), (ext, ext, hi))
    parts = []
    for kr, ir, jr in regions:
        ek, ei, ej = np.meshgrid(kr, ir, jr, indexing="ij")
        (bk, ok), (bi, oi), (bj, oj) = _axis(ek), _axis(ei), _axis(ej)
        s = sid[bk + 1, bi + 1, bj + 1]
        parts.append((s.astype(np.int64) * T ** 3
                      + (ok * T + oi) * T + oj).ravel())
    pos = np.concatenate(parts).astype(np.int32)
    pos.setflags(write=False)
    return pos


def surface_runs(spec: OrderingSpec, M: int, g: int, face: str):
    """(starts, lengths) of contiguous path-index runs for one face.

    This is the compressed form of the paper's precomputed index lists:
    a pack is then ``concatenate(data[start:start+len] for runs)`` — each
    run is one contiguous DMA on TPU (kernels/sfc_gather.py).
    """
    idx = surface_path_indices(spec, M, g, face)
    rl = run_lengths(idx)
    ends = np.cumsum(rl)
    starts_in_list = ends - rl
    starts = idx[starts_in_list]
    return starts.astype(np.int64), rl
