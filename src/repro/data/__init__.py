"""Deterministic, seekable synthetic data pipelines."""

from .pipeline import TokenPipeline, cube_loader  # noqa: F401
