"""Deterministic, seekable synthetic data pipelines.

- ``TokenPipeline``: structured synthetic LM tokens (Zipf unigrams +
  copy/induction spans so a model has something learnable). The batch at
  ``step`` is a pure function of (seed, step) ⇒ restart/elastic restore
  resumes the exact stream by cursor alone, any worker can regenerate any
  shard (no coordination), and stragglers can be re-issued idempotently.
- ``cube_loader``: initial states for gol3d, laid out under any ordering
  (SFC-tiled per DESIGN.md §2).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import OrderingSpec, ROW_MAJOR
from repro.core.orderings import path_to_rmo

__all__ = ["TokenPipeline", "cube_loader"]


@dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    induction_frac: float = 0.5  # fraction of sequence that is copied spans

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, step): {tokens, labels} int32."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        B, S, V = self.batch, self.seq + 1, self.vocab
        # Zipf-ish unigram draw (stable, heavy-tailed)
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = 1.0 / ranks
        p /= p.sum()
        toks = rng.choice(V, size=(B, S), p=p).astype(np.int32)
        # induction spans: copy an earlier window forward
        span = max(4, S // 16)
        n_spans = int(self.induction_frac * S / span / 2)
        for b in range(B):
            for _ in range(n_spans):
                src = rng.integers(0, S - 2 * span)
                dst = rng.integers(src + span, S - span)
                toks[b, dst:dst + span] = toks[b, src:src + span]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def cube_loader(M: int, density: float, seed: int,
                spec: OrderingSpec = ROW_MAJOR) -> np.ndarray:
    """(M³,) initial gol3d state in ``spec`` path order."""
    rng = np.random.default_rng(seed)
    cube = (rng.random((M, M, M)) < density).astype(np.float32)
    q = path_to_rmo(spec, M)
    return cube.reshape(-1)[q]
