"""AdamW + global-norm clip + warmup-cosine schedule, pure JAX.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
PartitionSpecs shard it (ZeRO-1: m/v live wherever the param shard
lives — 12 bytes/param spread over the data×model mesh plane).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "lr_at"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step, cfg: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    lr = lr_at(step, cfg)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/scalars exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gn, "lr": lr}
