"""Training substrate: optimizer, train step, fault-tolerant trainer."""

from .optimizer import OptConfig, init_opt_state, adamw_update, lr_at  # noqa: F401
from .train_step import TrainConfig, make_train_step, make_eval_step  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
