"""The jit'd training step: loss → grads → AdamW, with grad accumulation.

``make_train_step`` builds a pure (params, opt_state, batch) -> (params,
opt_state, metrics) function. Microbatching is a ``lax.scan`` over
leading batch splits with f32 gradient accumulation (bf16 activations,
f32 master weights/optimizer — standard mixed precision). Sharding is
applied by the caller (launch/train.py, launch/dryrun.py) via
in_shardings/out_shardings built from the model's PartitionSpecs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.zoo import Model

from .optimizer import OptConfig, adamw_update

__all__ = ["TrainConfig", "make_train_step", "make_eval_step"]


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    remat: bool | str = True  # True | False | "dots"


def make_train_step(model: Model, tcfg: TrainConfig):
    def loss_for_grads(params, batch):
        loss, (ce, aux) = model.loss(params, batch, remat=tcfg.remat)
        return loss, (ce, aux)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            n = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(loss_for_grads,
                                                  has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + loss), None

            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (gzero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
        else:
            (loss, _), grads = jax.value_and_grad(loss_for_grads,
                                                  has_aux=True)(params, batch)
        new_params, new_state, om = adamw_update(params, grads, opt_state,
                                                 tcfg.opt)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, (ce, aux) = model.loss(params, batch, remat=False)
        return {"loss": loss, "ce": ce, "aux": aux}
    return eval_step
