"""Fault-tolerant training loop: checkpoint/restart, straggler tracking.

Single-controller loop (the JAX model: one Python process drives all
devices; at multi-pod scale the same code runs under jax.distributed with
a process per host — the loop body is unchanged because all collectives
live inside the jit'd step).

Fault-tolerance contract:
- restart-safe: on startup, ``Trainer.run`` restores the newest intact
  checkpoint (atomic dirs ⇒ never a torn one) and resumes from its step
  and data cursor, bit-exact.
- periodic + final checkpoints, async (overlapped with compute).
- straggler mitigation: per-step wall time is tracked; steps slower than
  ``straggler_factor ×`` the running median are counted and surfaced in
  metrics. In a real fleet this signal feeds the launcher's hot-spare
  swap (see launch/elastic.py for the resharding half of that story).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.data.pipeline import TokenPipeline
from repro.models.zoo import Model

from .optimizer import init_opt_state
from .train_step import TrainConfig, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    train: TrainConfig = field(default_factory=TrainConfig)


class Trainer:
    def __init__(self, model: Model, pipeline: TokenPipeline,
                 tcfg: TrainerConfig, *, extra_batch=None):
        self.model = model
        self.pipe = pipeline
        self.tcfg = tcfg
        self.extra_batch = extra_batch or {}
        self.step_fn = jax.jit(make_train_step(model, tcfg.train),
                               donate_argnums=(0, 1))
        self.metrics_log: list[dict] = []

    def _init_state(self):
        params = self.model.init(jax.random.PRNGKey(0))
        opt_state = init_opt_state(params)
        return params, opt_state, 0

    def run(self, resume: bool = True):
        tcfg = self.tcfg
        start_step = 0
        params = opt_state = None
        if resume and ckpt.latest_step(tcfg.ckpt_dir) is not None:
            tree, meta = ckpt.restore(tcfg.ckpt_dir)
            params, opt_state = tree["params"], tree["opt_state"]
            start_step = int(meta["step"])
            print(f"[trainer] resumed from step {start_step}")
        if params is None:
            params, opt_state, start_step = self._init_state()

        times: list[float] = []
        stragglers = 0
        for step in range(start_step, tcfg.total_steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.pipe.batch_at(step).items()}
            batch.update(self.extra_batch)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            dt = time.perf_counter() - t0
            times.append(dt)
            med = float(np.median(times[-50:]))
            if len(times) > 5 and dt > tcfg.straggler_factor * med:
                stragglers += 1
            metrics.update(step=step, step_time=dt, stragglers=stragglers)
            self.metrics_log.append(metrics)
            if step % tcfg.log_every == 0:
                print(f"[trainer] step {step} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f} ms")
            if (step + 1) % tcfg.ckpt_every == 0:
                ckpt.save_async(tcfg.ckpt_dir, step + 1,
                                {"params": params, "opt_state": opt_state},
                                meta={"step": step + 1,
                                      "data_cursor": step + 1})
        ckpt.wait()
        ckpt.save(tcfg.ckpt_dir, tcfg.total_steps,
                  {"params": params, "opt_state": opt_state},
                  meta={"step": tcfg.total_steps,
                        "data_cursor": tcfg.total_steps})
        return params, opt_state, self.metrics_log
