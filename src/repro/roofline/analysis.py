"""Roofline terms from compiled artefacts (no hardware required).

Per (arch × shape × mesh) cell:

    compute term    = HLO_FLOPs / peak_FLOPs            [s]
    memory term     = HLO_bytes_accessed / HBM_bw       [s]
    collective term = collective_bytes / ICI_link_bw    [s]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the SPMD
partitioner has already divided by device count — the compiled module IS
the per-device program). Collective bytes are not in cost_analysis; we
parse the optimized HLO text and sum result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s per ICI link. The collective term charges bytes against ONE link
(a 1D-ring collective keeps one send link busy; bidirectional/multi-axis
overlap would halve it — we take the conservative bound and note it).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HW", "collective_bytes", "RooflineCell", "analyze"]

HW = {
    "flops_bf16": 197e12,
    "flops_f32": 98.5e12,   # v5e f32 ~ half bf16 MXU rate (model)
    "hbm_bw": 819e9,
    "ici_link_bw": 50e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# e.g. "bf16[8,4096,960]{2,1,0}" — capture dtype and dims
_SHAPE_RE = re.compile(r"(pred|[sbufc]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes of collective ops in optimized HLO, by op kind.

    Matches lines of the form ``%name = <shape> <op>(...)`` (also fused/
    async started ops like all-gather-start).
    """
    out = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        for op in _COLL_OPS:
            # op name directly before '(' — avoids matching metadata
            m = re.search(rf"\)?\s({op}(?:-start|-done)?)\(", " " + rhs)
            if m:
                if m.group(1).endswith("-done"):
                    break  # counted at -start
                # result shape = text before the op name
                head = rhs[:m.start(1)]
                out[op] += _shape_bytes(head)
                break
    return out


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: dict = field(default_factory=dict)
    model_flops_global: float = 0.0   # 6·N·D (active params × tokens)
    memory_per_device: dict = field(default_factory=dict)
    xla_raw: dict = field(default_factory=dict)  # loop-blind reference

    @property
    def t_compute(self) -> float:
        return self.flops / HW["flops_bf16"]

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return sum(self.coll_bytes.values()) / HW["ici_link_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × devices): remat/redundancy waste."""
        total = self.flops * self.n_devices
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation at the roofline bound."""
        peak = HW["flops_bf16"] * self.n_devices
        return (self.model_flops_global / self.t_bound) / peak \
            if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
            "model_flops_global": self.model_flops_global,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
            "memory_per_device": self.memory_per_device,
            "xla_raw": self.xla_raw,
        }


def analyze(arch, shape, mesh_name, n_devices, compiled, model_flops_global,
            hlo_text=None) -> RooflineCell:
    """Roofline terms from the compiled per-device module.

    flops/bytes/collectives come from the loop-aware HLO analyzer
    (roofline/hlo_cost.py) because XLA's cost_analysis counts while
    bodies once, and this framework scans over layers (EXPERIMENTS.md
    §Dry-run notes the correction; XLA's raw numbers are recorded too).
    """
    from .hlo_cost import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0)
                       + getattr(mem, "output_size_in_bytes", 0)),
    }
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo(text)
    cell = RooflineCell(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops=hc.flops,
        bytes_accessed=hc.bytes,
        coll_bytes={k: v for k, v in hc.coll_bytes.items() if v},
        model_flops_global=model_flops_global,
        memory_per_device=mem_d,
    )
    cell.xla_raw = {"flops": float(cost.get("flops", 0.0)),
                    "bytes accessed": float(cost.get("bytes accessed", 0.0)),
                    "unknown_trip_loops": hc.unknown_trip_loops}
    return cell
