"""Loop-aware cost model over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body
ONCE, ignoring trip count — and this framework deliberately puts every
layer stack, attention q-chunk loop, CE chunk loop and SSD chunk loop
under ``lax.scan`` (to keep HLO size O(1) in depth). XLA's numbers are
therefore ~L× too small. This module re-derives

    flops, bytes_accessed, collective bytes (by kind)

from ``compiled.as_text()`` with loop expansion: a ``while`` contributes
``trip × (body + cond)``; trip counts are read from the loop-condition
computation's integer constant (lax.scan emits a static bound).

Op cost model (dots dominate ≫99% of model flops):
- dot:       2 · |out| · K   (K = product of lhs contracting dims)
- reduce/elementwise/exp-family: |out| (1 flop per element)
- fusion:    flops of the fused computation; bytes at the fusion
             boundary only (operands + result), like XLA
- call/conditional: flops/bytes of the callee (conditional: max branch)
- collectives: result bytes, multiplied through enclosing loop trips
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|[su]\d+|c64|c128)\[([\d,]*)\]")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "cosine", "sine", "logistic", "expm1", "log1p",
    "atan2", "remainder", "compare", "select", "and", "or", "xor", "not",
    "clamp", "round-nearest-even", "round-nearest-afz", "cbrt", "erf",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Operand references in an op's argument list. Newer XLA dumps print the
# operand *type inline* ("dot(f32[256,512]{1,0} %Arg_0.1, ...)"), so the
# first whitespace-delimited token is no longer the first operand name —
# always take %-prefixed symbols.
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _operand_names(args: str) -> list[str]:
    return _OPERAND_RE.findall(args)


def _first_operand(args: str) -> str | None:
    names = _OPERAND_RE.findall(args)
    return names[0] if names else None


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES.get(dtype, 4)
    return elems, nbytes


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    args: str
    attrs: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # symbol -> type str


_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _split_rhs(rhs: str):
    """rhs -> (type_str, opcode, args, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):  # tuple result type
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rhs[:i + 1], rhs[i + 1:].strip()
    else:
        # type is everything before " opcode(" — opcode is lowercase token
        m = re.search(r"\s([a-z][\w\-]*)\(", rhs)
        if not m:
            return rhs, "", "", ""
        type_str, rest = rhs[:m.start()], rhs[m.start():].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return type_str, "", "", ""
    opcode = m.group(1)
    depth = 0
    for i in range(m.end() - 1, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    args = rest[m.end():i]
    attrs = rest[i + 1:]
    return type_str, opcode, args, attrs


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
                m = _HDR_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(2))
                    if m.group(1):
                        comps["__entry__"] = cur
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        type_str, opcode, args, attrs = _split_rhs(rhs)
        cur.types[name] = type_str
        if opcode:
            cur.ops.append(Op(name, type_str, opcode, args, attrs))
    return comps


def _callee(attrs: str, key: str) -> str | None:
    m = re.search(rf"{key}=%([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _dot_flops(op: Op, comp: Computation, global_types: dict) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", op.attrs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    k = 1
    # lhs type: prefer the inline operand type (the first shape token in the
    # argument list, when the dump prints operand shapes), else look the
    # first operand name up in the symbol tables.
    sm = _SHAPE_RE.search(op.args)
    if sm is None:
        lhs_name = _first_operand(op.args)
        lhs_type = comp.types.get(lhs_name) or global_types.get(lhs_name, "")
        sm = _SHAPE_RE.search(lhs_type)
    if sm:
        dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
        for c in cdims:
            if c < len(dims):
                k *= dims[c]
    return 2.0 * out_elems * k


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    unknown_trip_loops: int = 0
    bytes_by_op: dict = field(default_factory=dict)

    def add_bytes(self, opcode: str, nbytes: float):
        self.bytes += nbytes
        self.bytes_by_op[opcode] = self.bytes_by_op.get(opcode, 0.0) + nbytes

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops


def analyze_hlo(text: str) -> HloCost:
    comps = parse_module(text)
    entry = comps.get("__entry__")
    global_types: dict[str, str] = {}
    for c in comps.values():
        global_types.update(c.types)

    fused = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                callee = _callee(op.attrs, "calls")
                if callee:
                    fused.add(callee)

    # TPU-semantics byte attribution inside fused computations.
    #
    # 1. A parameter consumed only through *slicing* (dynamic-slice /
    #    gather / slice — possibly via convert/bitcast/copy/reshape
    #    pass-through chains, which XLA:CPU inserts to promote bf16 but a
    #    TPU fuses for free) costs slice bytes, not the full buffer.
    #    Crucial for scan-stacked weights and decode caches, where the
    #    full (L, …) array would otherwise be charged per iteration (L×).
    # 2. A parameter consumed as the *updated operand* of a
    #    dynamic-update-slice is aliased in place: traffic = update size.
    # 3. A fusion whose root is a dynamic-update-slice writes the update
    #    region, not the whole result buffer.
    sliced_param_bytes: dict[str, dict[int, int]] = {}
    dus_root_out_bytes: dict[str, int] = {}
    _SLICERS = ("dynamic-slice", "gather", "slice")
    _PASSTHRU = ("convert", "bitcast", "copy", "reshape")
    for cname in fused:
        comp = comps.get(cname)
        if comp is None:
            continue
        pnames = {}
        uses: dict[str, list] = {}
        for op in comp.ops:
            if op.opcode == "parameter":
                m = re.match(r"\s*(\d+)", op.args)
                if m:
                    pnames[op.name] = int(m.group(1))
            for a in _operand_names(op.args):
                uses.setdefault(a, []).append(op)

        def sliced_bytes(name: str, depth: int = 0) -> int | None:
            """Traffic if `name` is only sliced/aliased; None = whole."""
            if depth > 12:
                return None
            total = 0
            for op in uses.get(name, []):
                first = _first_operand(op.args) or ""
                if op.opcode in _SLICERS and first == name:
                    _, ob = _shape_elems_bytes(op.type_str)
                    total += ob
                elif op.opcode == "dynamic-update-slice" and first == name:
                    args = _operand_names(op.args)
                    upd = args[1] if len(args) > 1 else None
                    ub = _shape_elems_bytes(comp.types.get(upd, ""))[1] \
                        if upd else 0
                    total += ub
                elif op.opcode in _PASSTHRU:
                    sub = sliced_bytes(op.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        per_param: dict[int, int] = {}
        for pname, idx in pnames.items():
            sb = sliced_bytes(pname)
            if sb is not None:
                per_param[idx] = sb
        sliced_param_bytes[cname] = per_param

        # root dynamic-update-slice (possibly behind pass-through ops)
        if comp.ops:
            root = comp.ops[-1]
            seen = 0
            while root.opcode in _PASSTHRU and seen < 4:
                first = _first_operand(root.args)
                nxt = next((o for o in comp.ops
                            if first and o.name == first), None)
                if nxt is None:
                    break
                root = nxt
                seen += 1
            if root.opcode == "dynamic-update-slice":
                args = _operand_names(root.args)
                upd = args[1] if len(args) > 1 else None
                if upd:
                    dus_root_out_bytes[cname] = _shape_elems_bytes(
                        comp.types.get(upd, ""))[1]

    cache: dict[tuple[str, bool], HloCost] = {}

    def trip_count(cond_name: str) -> float | None:
        cond = comps.get(cond_name)
        if cond is None:
            return None
        best = None
        for op in cond.ops:
            if op.opcode == "constant":
                m = re.match(r"\s*(\d+)\s*$", op.args)
                if m:
                    v = int(m.group(1))
                    best = v if best is None else max(best, v)
        return best

    def cost_of(name: str, in_fusion: bool) -> HloCost:
        key = (name, in_fusion)
        if key in cache:
            return cache[key]
        comp = comps.get(name)
        out = HloCost()
        cache[key] = out
        if comp is None:
            return out
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = _callee(op.attrs, "body")
                cond = _callee(op.attrs, "condition")
                trip = trip_count(cond) if cond else None
                if trip is None:
                    trip = 1
                    out.unknown_trip_loops += 1
                sub = HloCost()
                if body:
                    sub.add(cost_of(body, in_fusion))
                if cond:
                    sub.add(cost_of(cond, in_fusion))
                out.add(sub, trip)
            elif oc == "fusion":
                callee = _callee(op.attrs, "calls")
                if callee:
                    inner = cost_of(callee, True)
                    out.flops += inner.flops
                    for k, v in inner.coll_bytes.items():
                        out.coll_bytes[k] += v
                    out.unknown_trip_loops += inner.unknown_trip_loops
                if not in_fusion:
                    if callee in dus_root_out_bytes:
                        ob = dus_root_out_bytes[callee]  # in-place update
                    else:
                        _, ob = _shape_elems_bytes(op.type_str)
                    sliced = sliced_param_bytes.get(callee, {})
                    ib = 0
                    for i, a in enumerate(_operand_names(op.args)):
                        if i in sliced:
                            ib += sliced[i]  # slice traffic, not full buffer
                        else:
                            ib += _shape_elems_bytes(
                                comp.types.get(a, global_types.get(a, "")))[1]
                    out.add_bytes("fusion", ib + ob)
            elif oc in ("call", "async-start", "async-done"):
                callee = _callee(op.attrs, "to_apply") or _callee(op.attrs, "calls")
                if callee:
                    out.add(cost_of(callee, in_fusion))
            elif oc == "conditional":
                branches = re.findall(r"branch_computations={([^}]*)}", op.attrs)
                names = re.findall(r"%([\w\.\-]+)",
                                   branches[0]) if branches else []
                names += [n for n in (_callee(op.attrs, "true_computation"),
                                      _callee(op.attrs, "false_computation"))
                          if n]
                subs = [cost_of(n, in_fusion) for n in names]
                if subs:
                    best = max(subs, key=lambda s: s.flops + s.bytes)
                    out.add(best)
            elif oc == "dot":
                out.flops += _dot_flops(op, comp, global_types)
                if not in_fusion:
                    _, ob = _shape_elems_bytes(op.type_str)
                    ib = sum(_shape_elems_bytes(
                        comp.types.get(a, global_types.get(a, "")))[1]
                        for a in _operand_names(op.args))
                    out.add_bytes("dot", ib + ob)
            elif oc == "convolution":
                # out_elems × (2 × kernel spatial × in_features) — generic
                out_elems, ob = _shape_elems_bytes(op.type_str)
                out.flops += 2.0 * out_elems  # lower bound; none in our nets
                if not in_fusion:
                    out.add_bytes(oc, ob)
            else:
                base = oc.replace("-start", "")
                if base in _COLLECTIVES:
                    _, ob = _shape_elems_bytes(op.type_str)
                    out.coll_bytes[base] += ob
                if oc in _ELEMWISE or oc.startswith("reduce"):
                    elems, _ = _shape_elems_bytes(
                        op.type_str if not oc.startswith("reduce")
                        else comp.types.get(_first_operand(op.args) or "",
                                            op.type_str))
                    out.flops += elems
                if not in_fusion and oc not in (
                        "parameter", "constant", "tuple", "get-tuple-element",
                        "bitcast", "reshape"):
                    _, ob = _shape_elems_bytes(op.type_str)
                    if oc in ("dynamic-slice", "slice", "gather", "broadcast",
                              "iota"):
                        # traffic = slice out (read) + out (write)
                        out.add_bytes(oc, 2 * ob)
                    elif oc in ("dynamic-update-slice", "scatter"):
                        # traffic = update operand (read) + written region;
                        # the full buffer is aliased, not rewritten
                        args = _operand_names(op.args)
                        upd = args[1] if len(args) > 1 else None
                        ub = _shape_elems_bytes(
                            comp.types.get(upd, global_types.get(upd, "")))[1] \
                            if upd else 0
                        out.add_bytes(oc, 2 * ub)
                    else:
                        ib = sum(_shape_elems_bytes(
                            comp.types.get(a, global_types.get(a, "")))[1]
                            for a in _operand_names(op.args))
                        out.add_bytes(oc, ib + ob)
        return out

    if entry is None:
        return HloCost()
    total = HloCost()
    total.add(cost_of(entry.name, False))
    return total
