"""Roofline analysis from compiled artefacts."""

from .analysis import HW, RooflineCell, analyze, collective_bytes  # noqa: F401
from .hlo_cost import HloCost, analyze_hlo  # noqa: F401
