"""mamba2-2.7b [ssm]: SSD, attention-free (arXiv:2405.21060).

64L d_model=2560, ssm_state=128, head_dim=64 (H=80), expand=2,
vocab=50280. The paper's SFC technique is inapplicable to the SSD
recurrence (DESIGN.md §Arch-applicability) — arch implemented without it.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    vocab_pad_multiple=256,
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                  conv_width=4, chunk=256),
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0, vocab=512,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=8),
    activation_dtype="float32",
)
