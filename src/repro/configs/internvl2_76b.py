"""internvl2-76b [vlm]: InternViT (stub) + LLaMA-70B-class LM
(arXiv:2404.16821).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256, head_dim=128.
Frontend stubbed per assignment: ``input_specs`` provides 256 precomputed
ViT patch embeddings (vit_dim=3200, InternViT-6B width) which a learned
projector maps to d_model and prepends to the token sequence.
"""

from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    vlm=VLMConfig(n_patches=256, vit_dim=3200),
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke", family="vlm",
    n_layers=3, d_model=96, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=12,
    vlm=VLMConfig(n_patches=8, vit_dim=48),
    activation_dtype="float32",
)
