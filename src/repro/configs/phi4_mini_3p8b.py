"""phi4-mini-3.8b [dense]: RoPE SwiGLU GQA (arXiv:2412.08905).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, head_dim=128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064, head_dim=128,
)

SMOKE = ModelConfig(
    name="phi4-mini-3.8b-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=256, vocab=512, head_dim=16, activation_dtype="float32",
)
