"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).

38L d_model=2048, ssm_state=64, head_dim=64 (H=64), expand=2;
one weight-shared GQA block (32H, d_ff=8192) applied every 6 layers.
vocab=32000.
"""

from repro.models.config import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                  conv_width=4, chunk=256),
    hybrid=HybridConfig(period=6, shared_d_ff=8192, shared_n_heads=32,
                        shared_n_kv_heads=32),
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=8),
    hybrid=HybridConfig(period=2, shared_d_ff=128, shared_n_heads=4,
                        shared_n_kv_heads=4),
    activation_dtype="float32",
)
