"""smollm-360m [dense]: llama-arch small (hf:HuggingFaceTB/SmolLM family).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, head_dim=64.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, head_dim=64, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=3, n_kv_heads=1,
    d_ff=256, vocab=512, head_dim=32, activation_dtype="float32",
)
