"""Architecture registry: --arch <id> → config, shape suite, input specs.

The 10 assigned architectures × their 4 LM shapes = 40 cells. Per the
assignment, ``long_500k`` requires sub-quadratic attention and is run
only for the SSM/hybrid/sliding-window archs (mamba2-2.7b, zamba2-1.2b,
gemma3-1b); it is recorded as SKIP (with reason) for the pure
full-attention archs — see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke", "input_specs",
           "cells", "shape_skip_reason", "LONG_OK"]

_MODULES = {
    "smollm-360m": "smollm_360m",
    "gemma3-1b": "gemma3_1b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-small": "whisper_small",
    "internvl2-76b": "internvl2_76b",
    "zamba2-1.2b": "zamba2_1p2b",
    "mamba2-2.7b": "mamba2_2p7b",
}

ARCHS = tuple(_MODULES)

# archs for which long_500k decode is sub-quadratic-legal
LONG_OK = ("mamba2-2.7b", "zamba2-1.2b", "gemma3-1b")


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").SMOKE


def shape_skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_OK:
        return ("full-attention arch: 512k dense KV decode is the "
                "quadratic-prefill regime the assignment skips")
    return None


def cells(include_skipped: bool = False):
    """All (arch, shape) pairs, minus documented skips."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if include_skipped or shape_skip_reason(a, s) is None:
                out.append((a, s))
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *, batch_override=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train/prefill → the full-sequence batch dict; decode → the one-token
    batch dict (cache specs come from Model.abstract_cache — they are a
    *state* operand, produced separately so the dry-run can shard them).
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    def sd(shape_, dtype):
        return jax.ShapeDtypeStruct(shape_, dtype)

    if shape.mode in ("train", "prefill"):
        if cfg.family == "vlm":
            st = S - cfg.vlm.n_patches
            return {
                "tokens": sd((B, st), i32),
                "labels": sd((B, st), i32),
                "patches": sd((B, cfg.vlm.n_patches, cfg.vlm.vit_dim), f32),
            }
        if cfg.family == "encdec":
            return {
                "tokens": sd((B, S), i32),
                "labels": sd((B, S), i32),
                "frames": sd((B, cfg.encdec.n_frames, cfg.d_model), f32),
            }
        return {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
    # decode: one new token against a seq_len-deep cache
    return {"tokens": sd((B, 1), i32), "cur": sd((), i32)}


def concrete_batch(cfg: ModelConfig, shape: ShapeSpec, *, batch_override=None,
                   seed: int = 0):
    """Small-materialisation helper used by smoke tests/examples."""
    specs = input_specs(cfg, shape, batch_override=batch_override)
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32 and v.shape:
            hi = cfg.vocab if k in ("tokens", "labels") else max(
                shape.seq_len, 2)
            out[k] = jnp.asarray(rng.integers(0, hi, v.shape, dtype=np.int32))
        elif v.dtype == jnp.int32:
            out[k] = jnp.zeros((), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
    return out
