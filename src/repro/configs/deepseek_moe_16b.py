"""deepseek-moe-16b [moe]: fine-grained expert segmentation (arXiv:2401.06066).

28L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=102400.
MoE: 2 shared + 64 routed, top-6, first layer dense.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
                  first_k_dense=1),
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=3, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=512, head_dim=24,
    moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_ff_expert=64,
                  first_k_dense=1, capacity_factor=4.0),
    activation_dtype="float32",
)
