"""gemma3-1b [dense]: 5:1 local:global sliding-window (hf:google/gemma-3-1b-pt).

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
window=512, global layer every 6th, global rope theta 1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    sliding_window=512, global_every=6,
    rope_theta=1e4, global_rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke", family="dense",
    n_layers=4, d_model=96, n_heads=2, n_kv_heads=1,
    d_ff=256, vocab=512, head_dim=48,
    sliding_window=8, global_every=2,
    rope_theta=1e4, global_rope_theta=1e6, activation_dtype="float32",
)
