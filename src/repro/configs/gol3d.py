"""gol3d application configs (the paper's own experiment grid).

Paper §4: problem sizes M ∈ {64, 128, 256}, stencil g ∈ {1..4},
orderings ∈ {row-major, Morton, Hilbert}, halo widths {1, 2}.
"""

from repro.core import HILBERT, MORTON, ROW_MAJOR
from repro.stencil.gol3d import Gol3dConfig

ORDERINGS = (ROW_MAJOR, MORTON, HILBERT)
PROBLEM_SIZES = (64, 128, 256)
STENCILS = (1, 2, 3, 4)
HALO_WIDTHS = (1, 2)

CONFIG = Gol3dConfig(M=64, g=1, ordering=MORTON, block_T=8)
SMOKE = Gol3dConfig(M=16, g=1, ordering=MORTON, block_T=4)
