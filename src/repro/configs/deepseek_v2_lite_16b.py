"""deepseek-v2-lite-16b [moe]: MLA + fine-grained MoE (arXiv:2405.04434).

27L d_model=2048 16H d_ff=1408(expert) vocab=102400.
MLA: kv_lora_rank=512, qk_nope=128, qk_rope=64, v=128.
MoE: 64 routed + 2 shared, top-6, first layer dense.

Note: the assignment line lists both "MoE 64e top-6" and "160 routed";
160 routed is DeepSeek-V2 (236B), not Lite — we follow the authoritative
"64e top-6" bracket (see DESIGN.md §5).
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
                  first_k_dense=1),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke", family="moe",
    n_layers=3, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=512, head_dim=24,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
    moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_ff_expert=64,
                  first_k_dense=1, capacity_factor=4.0),
    activation_dtype="float32",
)
