"""Architecture configs: one module per assigned arch + the paper's gol3d."""

from .registry import (  # noqa: F401
    ARCHS, SHAPES, LONG_OK, get_config, get_smoke, input_specs, cells,
    shape_skip_reason, concrete_batch,
)
