"""whisper-small [audio]: enc-dec backbone, conv frontend stubbed
(arXiv:2212.04356).

12L(dec)+12L(enc) d_model=768 12H d_ff=3072 vocab=51865; encoder sees
1500 precomputed frame embeddings (``input_specs`` provides them).
Decoder uses RoPE instead of whisper's learned 448-position table so the
assigned 32k stress shapes are well-defined (DESIGN.md §5).
"""

from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    vocab_pad_multiple=256,
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    encdec=EncDecConfig(n_enc_layers=12, n_frames=1500),
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="encdec",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512, head_dim=24,
    encdec=EncDecConfig(n_enc_layers=2, n_frames=16),
    activation_dtype="float32",
)
