"""Production serving launcher: one front door for both serving paths.

Default mode — batched greedy LM decode with a preallocated cache (the
dry-run's decode_32k/long_500k step, driven end-to-end)::

    python -m repro.launch.serve --arch gemma3-1b --smoke --new-tokens 16

``--stencil`` mode — the hardened ROI-query service over a curve-ordered
stencil block store (serve/service.py, DESIGN.md §11), mirroring
``launch/elastic.py --stencil``: advance a ResidentPipeline a few steps,
snapshot its block store, and drive a batched ROI query demo through the
full fault matrix (slow/failed fetch, bit-flipped payloads, cache
poison, deadline pressure, admission control), printing a per-request
deadline/outcome summary::

    python -m repro.launch.serve --stencil --M 32 --ordering hilbert \
        --queries 12 --deadline-ms 50 --faults
"""

from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LM mode: model config")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    # stencil ROI-service mode
    ap.add_argument("--stencil", action="store_true",
                    help="serve ROI queries over a stencil block store "
                         "instead of LM decode")
    ap.add_argument("--M", type=int, default=32)
    ap.add_argument("--T", type=int, default=8)
    ap.add_argument("--ordering", default="hilbert")
    ap.add_argument("--rule", default="gol")
    ap.add_argument("--bc", default="periodic")
    ap.add_argument("--steps", type=int, default=4,
                    help="pipeline steps before the snapshot is served")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--deadline-ms", type=float, default=100.0)
    ap.add_argument("--cache-blocks", type=int, default=256)
    ap.add_argument("--max-in-flight", type=int, default=4)
    ap.add_argument("--faults", action="store_true",
                    help="inject the serving fault matrix (failed + "
                         "bit-flipped fetches, cache poison)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def lm_main(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_smoke
    from repro.models import build_model
    from repro.serve import greedy_decode

    if args.arch is None:
        raise SystemExit("LM mode needs --arch (or pass --stencil)")
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("frontend-stubbed archs: see examples/serve_lm.py")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len), np.int32))
    t0 = time.perf_counter()
    out = greedy_decode(model, params, prompts, args.new_tokens,
                        args.prompt_len + args.new_tokens + 1)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    n = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")


def _demo_rois(M: int, T: int, n: int, seed: int):
    """Deterministic ROI mix: aligned power-of-two boxes (the
    best-case contiguity suite) plus arbitrary unaligned boxes."""
    import numpy as np

    from repro.serve import ROI

    rois = [ROI((0, 0, 0), (M // 2,) * 3),
            ROI((M // 2,) * 3, (M,) * 3),
            ROI((0, 0, 0), (M, M // 2, M // 2))]
    rng = np.random.default_rng(seed)
    while len(rois) < n:
        lo = rng.integers(0, M - T, 3)
        ext = rng.integers(T, M // 2 + 1, 3)
        hi = np.minimum(lo + ext, M)
        rois.append(ROI(tuple(int(v) for v in lo),
                        tuple(int(v) for v in hi)))
    return rois[:n]


def stencil_main(args) -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.faults import ServeFaultPlan, initial_state
    from repro.serve import StencilQueryService, StoreLayout
    from repro.stencil import ResidentPipeline

    pipe = ResidentPipeline(M=args.M, T=args.T, rule=args.rule, bc=args.bc,
                            kind=args.ordering)
    state0 = initial_state(args.rule, args.M, seed=args.seed)
    cube = pipe.run(jnp.asarray(state0), args.steps)
    store = np.asarray(pipe.to_blocks(cube))
    layout = StoreLayout.from_pipeline(pipe)
    print(f"[serve] stencil snapshot: rule={args.rule} M={args.M} "
          f"T={args.T} ordering={args.ordering} C={layout.channels} "
          f"({layout.nb} blocks) after {args.steps} steps")

    svc = StencilQueryService(
        store=store, layout=layout, cache_blocks=args.cache_blocks,
        deadline_s=args.deadline_ms / 1e3, max_in_flight=args.max_in_flight)
    if args.faults:
        plan = ServeFaultPlan(fail_first=2, bitflip_first=1)
        svc.fetch = plan.wrap_fetch(svc.fetch)
        print("[serve] fault injection ON: first 2 fetches fail, "
              "next payload bit-flipped")

    rois = _demo_rois(args.M, args.T, args.queries, args.seed)
    t0 = time.perf_counter()
    results = svc.query_batch(rois)
    dt = time.perf_counter() - t0

    dense = np.asarray(cube)
    for i, (roi, r) in enumerate(zip(rois, results)):
        line = (f"[serve]  q{i:02d} {roi.lo}->{roi.hi} "
                f"status={r.status:9s} ranges={len(r.ranges):2d} "
                f"hits={r.cache_hits:3d} misses={r.cache_misses:3d} "
                f"retries={r.retries} deadline={r.elapsed_s * 1e3:6.1f}ms")
        if r.status in ("ok", "degraded") and r.payload is not None:
            sl = tuple(slice(l, h) for l, h in zip(roi.lo, roi.hi))
            want = dense[(Ellipsis,) + sl]
            served = ~np.isnan(r.payload) if r.status == "degraded" \
                else np.ones_like(r.payload, bool)
            exact = bool(np.array_equal(np.asarray(r.payload)[served],
                                        np.asarray(want)[served]))
            line += f" exact={exact} missing={list(r.missing_ranges)}"
            if not exact:
                raise SystemExit(f"payload mismatch on q{i}")
        print(line)

    by = {}
    for r in results:
        by[r.status] = by.get(r.status, 0) + 1
    s = svc.stats()
    print(f"[serve] {len(results)} queries in {dt * 1e3:.1f}ms: "
          + " ".join(f"{k}={v}" for k, v in sorted(by.items())))
    print(f"[serve] cache: {s['cache_hits']} hits / {s['cache_misses']} "
          f"misses ({s['cached_blocks']} resident), "
          f"fetches={s['fetch_calls']} retries={s['retries']} "
          f"integrity_failures={s['integrity_failures']} "
          f"quarantined={s['quarantined']} shed={s['shed']}")
    print("SERVE_DONE")


def main():
    args = build_parser().parse_args()
    if args.stencil:
        stencil_main(args)
    else:
        lm_main(args)


if __name__ == "__main__":
    main()
