"""Production serving launcher: batched greedy decode with a preallocated
cache (the dry-run's decode_32k/long_500k step, driven end-to-end).

    python -m repro.launch.serve --arch gemma3-1b --smoke --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import build_model
from repro.serve import greedy_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("frontend-stubbed archs: see examples/serve_lm.py")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len), np.int32))
    t0 = time.perf_counter()
    out = greedy_decode(model, params, prompts, args.new_tokens,
                        args.prompt_len + args.new_tokens + 1)
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    n = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
