import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax import —
# jax locks the device count at first initialisation)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (train_step for
train_4k, prefill for prefill_32k, serve_step for decode shapes) with
full production shardings, lowers it against ShapeDtypeStructs (zero
allocation), compiles it, prints memory/cost analysis, and writes the
roofline terms to ``experiments/dryrun/<arch>_<shape>_<mesh>.json``.

Usage:
    python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --all --mesh single --opt act_seq_shard=0
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config, input_specs, shape_skip_reason
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import abstract_params, build_model
from repro.models.params import partition_specs
from repro.roofline.analysis import analyze
from repro.serve import make_serve_step
from repro.train import TrainConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_specs(mesh, specs, abstracts):
    """Drop sharding on any dim the mesh axis doesn't divide.

    jit rejects non-divisible shardings on *arguments* (e.g. vocab 51865
    on a 16-way axis, 5 kv heads on 16-way TP). Production frameworks pad
    such dims; the baseline replicates them instead (vocab padding is a
    §Perf item). Logs nothing — the dry-run JSON records final specs.
    """
    def fix(spec, sds):
        parts = list(spec) + [None] * (sds.ndim - len(spec))
        out = []
        for dim, axis in zip(sds.shape, parts):
            out.append(axis if dim % _axis_size(mesh, axis) == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, abstracts,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_specs(specs_tree, baxes):
    """P(batch_axes, None, ...) for every array input; scalars replicated."""
    def one(sds):
        if sds.ndim == 0:
            return P()
        return P(baxes, *([None] * (sds.ndim - 1)))
    return jax.tree.map(one, specs_tree)


DEFAULT_OPTS = {
    "act_seq_shard": 1,     # Megatron-SP residual sharding for train/prefill
    "remat": "1",   # "1" | "0" | "dots"
    "donate": 1,
    "microbatches": 1,
    "window_cache": 0,      # gemma3: truncate local-layer KV cache to window
    "score_shard": 1,       # decode: pin scores to the cache's seq sharding
    "flash": 0,             # Pallas attention kernel path (TPU deploy)
    "device_order": "hilbert",
}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             opts: dict) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    baxes = batch_axes(mesh)
    n_dev = mesh.devices.size

    if shape.mode in ("train", "prefill") and opts["act_seq_shard"]:
        cfg = dataclasses.replace(cfg, act_spec=(baxes, "model", None))
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, ep_axis="model")
    if opts["flash"]:
        cfg = dataclasses.replace(cfg, use_flash_kernel=True)
    model = build_model(cfg)

    t0 = time.time()
    if shape.mode == "train":
        params_abs = model.abstract(jnp.float32)
        pspecs = sanitize_specs(mesh, model.specs(), params_abs)
        opt_abs = {"m": params_abs, "v": params_abs,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
        batch_abs = input_specs(cfg, shape)
        bspecs = _batch_specs(batch_abs, baxes)
        rm = opts["remat"]
        rm = {"1": True, "0": False, 1: True, 0: False}.get(rm, rm)
        step = make_train_step(model, TrainConfig(
            microbatches=opts["microbatches"], remat=rm))
        in_sh = (_ns(mesh, pspecs), _ns(mesh, opt_specs), _ns(mesh, bspecs))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, opt_specs),
                  _ns(mesh, jax.tree.map(lambda _: P(),
                                         {"loss": 0, "grad_norm": 0, "lr": 0})))
        donate = (0, 1) if opts["donate"] else ()
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * model.n_active_params() * tokens
    elif shape.mode == "prefill":
        params_abs = model.abstract(jnp.bfloat16)
        pspecs = sanitize_specs(mesh, model.specs(), params_abs)
        batch_abs = input_specs(cfg, shape)
        bspecs = _batch_specs(batch_abs, baxes)

        def step(params, batch):
            return model.prefill(params, batch)

        in_sh = (_ns(mesh, pspecs), _ns(mesh, bspecs))
        vocab_rule = ("model" if cfg.vocab_padded % mesh.shape["model"] == 0
                      else None)
        jitted = jax.jit(step, in_shardings=in_sh,
                         out_shardings=NamedSharding(mesh, P(baxes, vocab_rule)))
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * model.n_active_params() * tokens
    else:  # decode
        params_abs = model.abstract(jnp.bfloat16)
        pspecs = sanitize_specs(mesh, model.specs(), params_abs)
        B, S = shape.global_batch, shape.seq_len
        cache_abs = model.abstract_cache(B, S, jnp.bfloat16)
        b_rule = baxes if B >= 8 else None
        # sequence-parallel decode cache: KV-head counts (1..8) don't
        # divide the 16-way TP axis, the 2^k sequence always does; B=1
        # (long_500k) additionally spreads seq over the batch axes.
        seq_rule = ("data", "model") if B == 1 else "model"
        if opts["score_shard"]:
            cfg = dataclasses.replace(
                cfg, score_spec=(b_rule, None, None, seq_rule))
            model = build_model(cfg)
        cache_specs = model.cache_specs(
            B, S, extra_rules={"batch": b_rule, "seq": seq_rule,
                               "kv_heads": None, "heads": None})
        cache_specs = sanitize_specs(mesh, cache_specs, cache_abs)
        batch_abs = input_specs(cfg, shape)
        bspecs = _batch_specs(batch_abs, b_rule)
        step = make_serve_step(model)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, cache_specs), _ns(mesh, bspecs))
        out_sh = (NamedSharding(mesh, P(b_rule)), _ns(mesh, cache_specs))
        donate = (1,) if opts["donate"] else ()
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)
        model_flops = 2.0 * model.n_active_params() * B
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cell = analyze(arch, shape_name, mesh_name, n_dev, compiled, model_flops)
    rec = cell.to_dict()
    rec.update(t_lower_s=t_lower, t_compile_s=t_compile, opts=dict(opts),
               n_params=model.n_params(), n_active=model.n_active_params())
    print(f"  memory_analysis: {mem}")
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e}")
    print(f"  roofline: compute {cell.t_compute*1e3:.2f} ms | memory "
          f"{cell.t_memory*1e3:.2f} ms | collective "
          f"{cell.t_collective*1e3:.2f} ms -> {cell.bottleneck}-bound, "
          f"useful-flops {cell.useful_flops_frac:.2f}, "
          f"MFU-bound {cell.mfu_bound:.2%}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", action="append", default=[],
                    help="key=val overrides, e.g. --opt act_seq_shard=0")
    args = ap.parse_args()

    opts = dict(DEFAULT_OPTS)
    for kv in args.opt:
        k, v = kv.split("=", 1)
        opts[k] = type(DEFAULT_OPTS.get(k, ""))(v) if k in DEFAULT_OPTS else v

    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        reason = shape_skip_reason(args.arch, args.shape)
        if reason:
            print(f"SKIP {args.arch} × {args.shape}: {reason}")
            return
        todo = [(args.arch, args.shape)]

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16",
                       make_production_mesh(multi_pod=False,
                                            device_order=opts["device_order"])))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True,
                                            device_order=opts["device_order"])))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for mesh_name, mesh in meshes:
        for arch, shape_name in todo:
            key = f"{arch}_{shape_name}_{mesh_name}{args.tag}"
            print(f"[dryrun] {key}")
            try:
                rec = run_cell(arch, shape_name, mesh, mesh_name, opts)
                with open(os.path.join(args.out, key + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # noqa: BLE001 — report-and-continue runner
                traceback.print_exc()
                failures.append((key, repr(e)))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for k, e in failures:
            print("  ", k, e)
        raise SystemExit(1)
    print(f"[dryrun] all {len(todo) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
