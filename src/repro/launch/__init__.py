"""Launchers: production mesh, multi-pod dry-run, train/serve drivers."""

from .mesh import (  # noqa: F401
    make_production_mesh, hilbert_device_permutation, batch_axes,
)
