"""Fault-injection harness for the checkpointed stencil pipelines.

Three fault families, matching docs/resilience.md's injection matrix:

- **Process death**: :class:`FaultPlan` builds the
  :class:`repro.stencil.runner.RunHooks` that kill the run at an exact
  step — either an in-process :class:`SimulatedCrash` (fast, for unit
  tests) or a hard ``os._exit(KILL_EXIT)`` (a real dead process, for the
  subprocess matrix). The kill fires *before* that step's checkpoint is
  written, so resume restarts from the previous interval.
- **Storage corruption**: helpers that truncate a chunk file, flip one
  bit in it, delete the manifest, or plant a dangling ``.tmp_step_*``
  dir — exercising ckpt.py's crc32 verification, quarantine, and
  newest-valid fallback.
- **State poison**: NaN/Inf (or any value) written into the running
  state at a step boundary — exercising the runner's health guards
  (RunHealthError instead of a poisoned checkpoint).

CLI (the subprocess kill/corrupt/resume matrix of the faults CI job)::

    python -m repro.launch.faults --mesh 2,2,2 --devices 8 \
        --ordering hilbert --rule gol --steps 24 --interval 8 \
        --kill-at 11 --ckpt-dir /tmp/ft     # dies with exit code 17
    python -m repro.launch.faults ... (same, no --kill-at)
                                            # resumes; prints FAULTS_DONE

A run that completes prints ``FAULTS_DONE step=<n> crc=<crc32>`` — the
crc of the canonical final state, so a resumed run can be asserted
bit-identical to an uninterrupted one across processes (and across
ordering/T/S/mesh changes between the two invocations).
"""

import os

if __name__ == "__main__":  # set before jax init — see elastic.py
    import argparse

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=1)
    _ap.add_argument("--mesh", default="",
                     help="px,py,pz for DistributedPipeline; empty = "
                          "single-device ResidentPipeline")
    _ap.add_argument("--ordering", default="hilbert")
    _ap.add_argument("--rule", default="gol")
    _ap.add_argument("--M", type=int, default=8,
                     help="local (per-shard) / resident cube edge")
    _ap.add_argument("--T", type=int, default=8)
    _ap.add_argument("--S", type=int, default=1)
    _ap.add_argument("--bc", default="periodic")
    _ap.add_argument("--steps", type=int, default=24)
    _ap.add_argument("--interval", type=int, default=8)
    _ap.add_argument("--kill-at", type=int, default=None)
    _ap.add_argument("--kill-mode", default="exit",
                     choices=["exit", "raise"])
    _ap.add_argument("--ckpt-dir", required=True)
    _ap.add_argument("--seed", type=int, default=0)
    _ARGS = _ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_ARGS.devices}")

import glob  # noqa: E402
import shutil  # noqa: E402
import zlib  # noqa: E402
from dataclasses import dataclass  # noqa: E402

import numpy as np  # noqa: E402

from repro.stencil.runner import RunHooks  # noqa: E402

KILL_EXIT = 17  # distinguishable from python tracebacks (1) and signals


class SimulatedCrash(RuntimeError):
    """In-process stand-in for a killed worker: aborts the run after the
    fault point with no cleanup, leaving whatever checkpoints exist."""


@dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule compiled to :class:`RunHooks`.

    kill_at_step:  die when the run reaches this step (before its
                   checkpoint is written)
    kill_mode:     "raise" (SimulatedCrash) | "exit" (os._exit(17) — a
                   real process death, nothing is flushed)
    poison_at_step: overwrite one site of the state at this step
    poison_value:  the injected value (default NaN)
    poison_site:   flat index of the poisoned site
    """
    kill_at_step: "int | None" = None
    kill_mode: str = "raise"
    poison_at_step: "int | None" = None
    poison_value: float = float("nan")
    poison_site: int = 0

    def break_steps(self) -> tuple:
        return tuple(s for s in (self.kill_at_step, self.poison_at_step)
                     if s is not None)

    def hooks(self) -> RunHooks:
        def on_boundary(step, canonical):
            if step == self.poison_at_step:
                out = np.array(canonical)
                out.reshape(-1)[self.poison_site] = self.poison_value
                return out
            if step == self.kill_at_step:
                if self.kill_mode == "exit":
                    os._exit(KILL_EXIT)
                raise SimulatedCrash(f"injected kill at step {step}")
            return None

        return RunHooks(break_at=self.break_steps(),
                        on_boundary=on_boundary)


# -- storage-corruption injectors (operate on finished checkpoints) ---------

def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _chunk_files(ckpt_dir: str, step: int) -> list:
    files = sorted(glob.glob(os.path.join(_step_dir(ckpt_dir, step),
                                          "arrays_*.npz")))
    if not files:
        raise FileNotFoundError(
            f"no chunk files under {_step_dir(ckpt_dir, step)}")
    return files


def truncate_chunk(ckpt_dir: str, step: int, keep_bytes: int = 8) -> str:
    """Tear a chunk file down to ``keep_bytes`` — a partial write that
    survived a crash. Restore must refuse it (unreadable npz)."""
    path = _chunk_files(ckpt_dir, step)[0]
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)
    return path


def bitflip_chunk(ckpt_dir: str, step: int, offset: "int | None" = None) -> str:
    """Flip one bit mid-file — silent media corruption. The npz may still
    parse; the per-leaf crc32 must catch it."""
    path = _chunk_files(ckpt_dir, step)[0]
    size = os.path.getsize(path)
    if offset is None:
        offset = size * 3 // 4  # inside the payload, past the zip header
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0x10]))
    return path


def drop_manifest(ckpt_dir: str, step: int) -> str:
    """Delete a checkpoint's manifest — the dir must stop counting as a
    valid candidate (latest_step skips it)."""
    path = os.path.join(_step_dir(ckpt_dir, step), "manifest.json")
    os.remove(path)
    return path


def make_dangling_tmp(ckpt_dir: str, step: int) -> str:
    """Plant a half-written ``.tmp_step_*`` dir (writer died pre-rename).
    Scans must ignore it entirely."""
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "arrays_00.npz"), "wb") as f:
        f.write(b"partial")
    return tmp


def wipe(ckpt_dir: str) -> None:
    shutil.rmtree(ckpt_dir, ignore_errors=True)


# -- serving fault matrix (serve/service.StencilQueryService) ---------------

@dataclass
class ServeFaultPlan:
    """Declarative fault schedule for the ROI-query service — wraps the
    service's ``fetch`` callable so every storage pathology of the
    serving matrix (docs/serving.md) is injectable per fetch call:

    fail_first:    first N fetch calls raise FetchError (transient
                   storage failure; the service's bounded retry must
                   absorb N <= max_retries, degrade beyond)
    slow_first:    first N fetch calls advance the service clock (or
                   really sleep) by ``slow_s`` before returning — the
                   slow-storage / deadline-pressure fault
    bitflip_first: first N fetch calls return a payload with one bit
                   flipped — silent media corruption; the service's
                   manifest crc must catch it (a typed retry, never a
                   wrong payload)

    Counters are mutable on purpose: one plan instance injects a finite
    burst and then behaves — the recovery path is the object under test.
    ``calls`` records every fetch the wrapped callable saw.
    """
    fail_first: int = 0
    slow_first: int = 0
    slow_s: float = 0.0
    bitflip_first: int = 0
    calls: int = 0

    def wrap_fetch(self, fetch, *, sleep=None):
        """``fetch(start, stop)`` with this plan's faults layered on.
        ``sleep`` (default time.sleep) is injectable so tests can drive
        a fake clock instead of waiting."""
        import time as _time

        from repro.serve.service import FetchError

        do_sleep = _time.sleep if sleep is None else sleep

        def faulty(start, stop):
            self.calls += 1
            n = self.calls
            if n <= self.slow_first and self.slow_s > 0:
                do_sleep(self.slow_s)
            if n <= self.fail_first:
                raise FetchError(f"injected fetch failure #{n} "
                                 f"on range [{start}, {stop})")
            data = np.array(fetch(start, stop))  # writable copy
            if n <= self.fail_first + self.bitflip_first:
                raw = data.reshape(-1).view(np.uint8)
                raw[raw.size // 3] ^= 0x20
            return data

        return faulty


# -- deterministic initial states (shared by CLI runs and tests) ------------

def initial_state(rule: str, shape, seed: int = 0) -> np.ndarray:
    """Deterministic rule-appropriate initial state for a global box
    ``shape`` (int or (Gk,Gi,Gj)); multi-field rules get (C, *shape)."""
    from repro.kernels.rules import get_rule

    if isinstance(shape, int):
        shape = (shape,) * 3
    C = get_rule(rule).channels
    full = tuple(shape) if C == 1 else (C,) + tuple(shape)
    r = np.random.default_rng(seed)
    if rule == "gol":
        return (r.random(full) < 0.35).astype(np.float32)
    return r.standard_normal(full).astype(np.float32)


def state_crc(state: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(state).tobytes())


# -- CLI driver -------------------------------------------------------------

def main(a) -> None:
    import jax  # noqa: F401  (devices forced above)

    from repro.core.orderings import ordering_from_name
    from repro.stencil import (CheckpointedRun, DistributedPipeline,
                               ResidentPipeline, make_stencil_mesh)

    plan = FaultPlan(kill_at_step=a.kill_at, kill_mode=a.kill_mode)
    if a.mesh:
        procs = tuple(int(x) for x in a.mesh.split(","))
        pipe = DistributedPipeline(
            mesh=make_stencil_mesh(procs), spec=ordering_from_name(a.ordering),
            M=a.M, T=a.T, S=a.S, rule=a.rule, bc=a.bc)
        shape = pipe.global_shape
    else:
        pipe = ResidentPipeline(M=a.M, T=a.T, S=a.S, rule=a.rule, bc=a.bc,
                                kind=a.ordering)
        shape = (a.M,) * 3
    run = CheckpointedRun(pipe, a.ckpt_dir, interval=a.interval,
                          hooks=plan.hooks() if a.kill_at is not None else None,
                          extra_meta={"seed": a.seed})
    state0 = initial_state(a.rule, shape, seed=a.seed)
    final = run.run(state0, a.steps)
    print(f"FAULTS_DONE step={a.steps} crc={state_crc(final):#010x}")


if __name__ == "__main__":
    main(_ARGS)
