"""Elastic re-scaling: restore a checkpoint onto a different mesh.

The fault-tolerance story at 1000+ nodes (DESIGN.md §6): when a pod (or
any 2^k slice) is lost, the job restarts on the surviving mesh; because
checkpoints store *logical* arrays, restore is a pure resharding. This
driver demonstrates/validates that end to end on host devices:

    python -m repro.launch.elastic --devices 8 --from-shape 4,2 --to-shape 2,2

It trains a few steps on mesh A, checkpoints, restores onto mesh B
(fewer "data" ways = a lost slice), continues, and asserts losses stay
finite and params match bit-exactly across the reshard.
"""

import os

if __name__ == "__main__":  # set before jax init — see dryrun.py
    import argparse

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _ap.add_argument("--from-shape", default="4,2")
    _ap.add_argument("--to-shape", default="2,2")
    _ARGS = _ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_ARGS.devices}")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import ckpt  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.data import TokenPipeline  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import OptConfig, TrainConfig, make_train_step  # noqa: E402
from repro.train.optimizer import init_opt_state  # noqa: E402


def _mesh(shape):
    return jax.make_mesh(tuple(shape), ("data", "model"))


def _shardings(mesh, model, params_abs):
    from repro.launch.dryrun import sanitize_specs
    pspecs = sanitize_specs(mesh, model.specs(), params_abs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def main():
    ckpt_dir = "/tmp/repro_elastic"
    import shutil
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    cfg = dataclasses.replace(
        get_config("smollm-360m"), n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        activation_dtype="float32")
    model = build_model(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq=32)
    tc = TrainConfig(opt=OptConfig(warmup_steps=2, total_steps=10))
    step = make_train_step(model, tc)

    from_shape = [int(x) for x in _ARGS.from_shape.split(",")]
    to_shape = [int(x) for x in _ARGS.to_shape.split(",")]

    # --- phase 1: train 3 steps on mesh A, checkpoint
    mesh_a = _mesh(from_shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    sh_a = _shardings(mesh_a, model, model.abstract())
    params = jax.device_put(params, sh_a)
    opt = {"m": jax.device_put(opt["m"], sh_a),
           "v": jax.device_put(opt["v"], sh_a), "step": opt["step"]}
    jstep = jax.jit(step)
    with mesh_a:
        for i in range(3):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, opt, m = jstep(params, opt, batch)
            print(f"[elastic] mesh {from_shape} step {i} "
                  f"loss {float(m['loss']):.4f}")
    ckpt.save(ckpt_dir, 3, {"params": params, "opt_state": opt},
              meta={"step": 3})
    host_before = jax.tree.map(np.asarray, params)

    # --- phase 2: restore onto mesh B (simulates losing a slice), continue
    mesh_b = _mesh(to_shape)
    sh_b = _shardings(mesh_b, model, model.abstract())
    tree, meta = ckpt.restore(ckpt_dir, shardings={
        "params": sh_b, "opt_state": {"m": sh_b, "v": sh_b}})
    params_b, opt_b = tree["params"], tree["opt_state"]
    opt_b["step"] = jnp.asarray(opt_b["step"])
    for a, b in zip(jax.tree.leaves(host_before),
                    jax.tree.leaves(jax.tree.map(np.asarray, params_b))):
        np.testing.assert_array_equal(a, b)
    print(f"[elastic] reshard {from_shape} -> {to_shape}: params bit-exact")
    with mesh_b:
        for i in range(meta["step"], meta["step"] + 3):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params_b, opt_b, m = jstep(params_b, opt_b, batch)
            loss = float(m["loss"])
            print(f"[elastic] mesh {to_shape} step {i} loss {loss:.4f}")
            assert np.isfinite(loss)
    print("[elastic] OK")


if __name__ == "__main__":
    main()
