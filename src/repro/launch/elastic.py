"""Elastic re-scaling: restore a checkpoint onto a different mesh.

The fault-tolerance story at 1000+ nodes (DESIGN.md §6): when a pod (or
any 2^k slice) is lost, the job restarts on the surviving mesh; because
checkpoints store *logical* arrays, restore is a pure resharding. This
driver demonstrates/validates that end to end on host devices:

    python -m repro.launch.elastic --devices 8 --from-shape 4,2 --to-shape 2,2

It trains a few steps on mesh A, checkpoints, restores onto mesh B
(fewer "data" ways = a lost slice), continues, and asserts losses stay
finite and params match bit-exactly across the reshard.
"""

import os

if __name__ == "__main__":  # set before jax init — see dryrun.py
    import argparse

    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _ap.add_argument("--from-shape", default="4,2")
    _ap.add_argument("--to-shape", default="2,2")
    _ap.add_argument("--stencil", action="store_true",
                     help="elastic-reshard a checkpointed stencil run "
                          "instead of the training loop")
    _ap.add_argument("--from-mesh", default="2,2,2")
    _ap.add_argument("--to-mesh", default="1,1,1")
    _ap.add_argument("--local-M", type=int, default=8,
                     help="per-shard cube edge on the FROM mesh")
    _ap.add_argument("--steps", type=int, default=12)
    _ap.add_argument("--interval", type=int, default=4)
    _ap.add_argument("--kill-at", type=int, default=6)
    _ARGS = _ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_ARGS.devices}")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import ckpt  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.data import TokenPipeline  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import OptConfig, TrainConfig, make_train_step  # noqa: E402
from repro.train.optimizer import init_opt_state  # noqa: E402


def _mesh(shape):
    return jax.make_mesh(tuple(shape), ("data", "model"))


def _shardings(mesh, model, params_abs):
    from repro.launch.dryrun import sanitize_specs
    pspecs = sanitize_specs(mesh, model.specs(), params_abs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def main():
    ckpt_dir = "/tmp/repro_elastic"
    import shutil
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    cfg = dataclasses.replace(
        get_config("smollm-360m"), n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=256, vocab=512,
        activation_dtype="float32")
    model = build_model(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq=32)
    tc = TrainConfig(opt=OptConfig(warmup_steps=2, total_steps=10))
    step = make_train_step(model, tc)

    from_shape = [int(x) for x in _ARGS.from_shape.split(",")]
    to_shape = [int(x) for x in _ARGS.to_shape.split(",")]

    # --- phase 1: train 3 steps on mesh A, checkpoint
    mesh_a = _mesh(from_shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    sh_a = _shardings(mesh_a, model, model.abstract())
    params = jax.device_put(params, sh_a)
    opt = {"m": jax.device_put(opt["m"], sh_a),
           "v": jax.device_put(opt["v"], sh_a), "step": opt["step"]}
    jstep = jax.jit(step)
    with mesh_a:
        for i in range(3):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, opt, m = jstep(params, opt, batch)
            print(f"[elastic] mesh {from_shape} step {i} "
                  f"loss {float(m['loss']):.4f}")
    ckpt.save(ckpt_dir, 3, {"params": params, "opt_state": opt},
              meta={"step": 3})
    host_before = jax.tree.map(np.asarray, params)

    # --- phase 2: restore onto mesh B (simulates losing a slice), continue
    mesh_b = _mesh(to_shape)
    sh_b = _shardings(mesh_b, model, model.abstract())
    tree, meta = ckpt.restore(ckpt_dir, shardings={
        "params": sh_b, "opt_state": {"m": sh_b, "v": sh_b}})
    params_b, opt_b = tree["params"], tree["opt_state"]
    opt_b["step"] = jnp.asarray(opt_b["step"])
    for a, b in zip(jax.tree.leaves(host_before),
                    jax.tree.leaves(jax.tree.map(np.asarray, params_b))):
        np.testing.assert_array_equal(a, b)
    print(f"[elastic] reshard {from_shape} -> {to_shape}: params bit-exact")
    with mesh_b:
        for i in range(meta["step"], meta["step"] + 3):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params_b, opt_b, m = jstep(params_b, opt_b, batch)
            loss = float(m["loss"])
            print(f"[elastic] mesh {to_shape} step {i} loss {loss:.4f}")
            assert np.isfinite(loss)
    print("[elastic] OK")


def stencil_main(a):
    """Elastic reshard of a *stencil* run (DESIGN.md §10): kill a
    checkpointed run mid-flight on mesh A, resume it on mesh B with a
    different ordering/T/S, and assert the final state is bit-identical
    to an uninterrupted single-device run.

        python -m repro.launch.elastic --stencil --devices 8 \
            --from-mesh 2,2,2 --to-mesh 1,1,1 --local-M 8
    """
    import shutil

    from repro.launch.faults import (FaultPlan, SimulatedCrash,
                                     initial_state)
    from repro.stencil import (CheckpointedRun, DistributedPipeline,
                               ResidentPipeline, make_stencil_mesh)
    from repro.core import HILBERT, MORTON

    ckpt_dir = "/tmp/repro_elastic_stencil"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    procs_a = tuple(int(x) for x in a.from_mesh.split(","))
    procs_b = tuple(int(x) for x in a.to_mesh.split(","))
    gshape = tuple(p * a.local_M for p in procs_a)
    locals_b = {g // p for g, p in zip(gshape, procs_b)}
    if len(locals_b) != 1:
        raise SystemExit(f"to-mesh {procs_b} gives non-cubic locals over "
                         f"global {gshape}")
    local_b = locals_b.pop()
    state0 = initial_state("gol", gshape, seed=0)

    # --- phase 1: run on mesh A, die at --kill-at (before its checkpoint)
    pipe_a = DistributedPipeline(mesh=make_stencil_mesh(procs_a),
                                 spec=HILBERT, M=a.local_M, T=8, S=2)
    run_a = CheckpointedRun(pipe_a, ckpt_dir, interval=a.interval,
                            hooks=FaultPlan(kill_at_step=a.kill_at,
                                            kill_mode="raise").hooks())
    try:
        run_a.run(state0, a.steps)
        raise SystemExit("injected kill did not fire")
    except SimulatedCrash:
        print(f"[elastic] mesh {procs_a} killed at step {a.kill_at}")

    # --- phase 2: resume on mesh B (lost slice), new ordering/T/S
    pipe_b = DistributedPipeline(mesh=make_stencil_mesh(procs_b),
                                 spec=MORTON, M=local_b, T=4, S=1)
    out = CheckpointedRun(pipe_b, ckpt_dir,
                          interval=a.interval).run(state0, a.steps)
    print(f"[elastic] resumed on mesh {procs_b} to step {a.steps}")

    # --- reference: uninterrupted resident run over the same global box
    if len(set(gshape)) == 1:
        ref_pipe = ResidentPipeline(M=gshape[0], T=8, S=1, kind="hilbert")
        ref = np.asarray(ref_pipe.run(jnp.asarray(state0), a.steps))
        np.testing.assert_array_equal(out, ref)
        print(f"[elastic] reshard {procs_a} -> {procs_b}: "
              f"state bit-exact vs uninterrupted run")
    print("[elastic] OK")


if __name__ == "__main__":
    if _ARGS.stencil:
        stencil_main(_ARGS)
    else:
        main()
