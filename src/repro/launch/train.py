"""Production training launcher.

On this container it runs the reduced configs on the single CPU device;
on a real fleet the SAME entry point runs under ``jax.distributed`` (one
process per host) with the production mesh — the step function and
shardings are identical to what launch/dryrun.py proves compiles for
(16,16) and (2,16,16).

    python -m repro.launch.train --arch smollm-360m --steps 100 --smoke
    python -m repro.launch.train --arch smollm-360m --mesh single  # fleet
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, get_smoke
from repro.data import TokenPipeline
from repro.models import build_model
from repro.train import OptConfig, Trainer, TrainerConfig, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config (CPU-sized)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit(f"{args.arch}: use a family-specific driver for the "
                         "stubbed-frontend archs (examples/)")
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {model.n_params()/1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=10,
        train=TrainConfig(
            opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
            microbatches=args.microbatches))
    Trainer(model, pipe, tcfg).run(resume=args.resume)


if __name__ == "__main__":
    main()
