"""Production meshes + Hilbert device ordering (the paper's placement idea).

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state). The single-pod mesh is (16,16) = 256 chips
("data","model"); the multi-pod mesh is (2,16,16) = 512 chips
("pod","data","model") — "pod" carries pure data parallelism so only
gradient all-reduce crosses the inter-pod (DCN) boundary.

Hilbert device ordering (DESIGN.md §2, process-placement row): logical
mesh axes are laid onto the physical torus along a 3D Hilbert curve, so
devices adjacent in the minor mesh axis are physically adjacent (1 ICI
hop) and blocks of 2^k consecutive devices occupy compact torus bricks —
the paper's locality argument applied to process placement. On real TPUs
the coords come from ``device.coords``; on placeholder CPU devices we
synthesise a (4,8,16)-ish torus so the permutation logic is exercised.
"""

from __future__ import annotations

import numpy as np

import jax

from repro.core.hilbert import hilbert_encode

__all__ = ["make_production_mesh", "hilbert_device_permutation",
           "MESH_AXES_SINGLE", "MESH_AXES_MULTI", "batch_axes"]

MESH_AXES_SINGLE = ("data", "model")
MESH_AXES_MULTI = ("pod", "data", "model")


def _torus_shape(n: int) -> tuple[int, int, int]:
    """A plausible 3D torus for n chips (power of two)."""
    dims = [1, 1, 1]
    i = 0
    while np.prod(dims) < n:
        dims[i % 3] *= 2
        i += 1
    return tuple(int(d) for d in sorted(dims))


def _device_coords(devices) -> np.ndarray:
    """(n,3) physical coordinates; real TPU coords when available."""
    coords = []
    for d in devices:
        c = getattr(d, "coords", None)
        if c is None:
            coords = None
            break
        coords.append(tuple(c)[:3])
    if coords is not None:
        return np.asarray(coords, dtype=np.int64)
    # placeholder devices: synthesise a torus in id order
    n = len(devices)
    tz = _torus_shape(n)
    idx = np.arange(n)
    return np.stack(np.unravel_index(idx, tz), axis=1).astype(np.int64)


def hilbert_device_permutation(devices) -> list:
    """Devices reordered along the 3D Hilbert curve through the torus.

    Consecutive devices in the returned order are torus-adjacent; any
    2^(3k) aligned block occupies a compact sub-brick — so a mesh built
    from this order gives minor-axis collectives single-hop rings and
    keeps "data"-axis blocks physically compact.
    """
    coords = _device_coords(devices)
    side = 1 << int(np.ceil(np.log2(max(coords.max() + 1, 2))))
    m = int(np.log2(side))
    key = hilbert_encode([coords[:, 0].astype(np.uint64),
                          coords[:, 1].astype(np.uint64),
                          coords[:, 2].astype(np.uint64)], max(m, 2))
    order = np.argsort(key.astype(np.int64), kind="stable")
    return [devices[int(i)] for i in order]


def make_production_mesh(*, multi_pod: bool = False,
                         device_order: str = "hilbert"):
    """The dry-run target mesh: (16,16) single pod / (2,16,16) two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entry "
            "point must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before importing jax")
    if device_order == "hilbert":
        devices = hilbert_device_permutation(devices)
    dev_array = np.asarray(devices, dtype=object).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
