"""Atomic, manifest-driven, elastic checkpointing."""

from . import ckpt  # noqa: F401
from .ckpt import save, save_async, wait, restore, latest_step  # noqa: F401
