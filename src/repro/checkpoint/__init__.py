"""Atomic, manifest-driven, elastic checkpointing."""

from . import ckpt  # noqa: F401
from .ckpt import (  # noqa: F401
    CheckpointCorruptError, latest_step, restore, save, save_async,
    valid_steps, wait,
)
