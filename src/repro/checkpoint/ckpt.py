"""Fault-tolerant checkpointing: atomic, manifest-driven, elastic.

Layout of a checkpoint directory::

    <dir>/step_000123/          # finished checkpoints only (atomic rename)
        manifest.json           # step, data cursor, rng, tree structure,
                                # leaf shapes/dtypes, shard chunking,
                                # per-leaf crc32 checksums
        arrays_00.npz ...       # leaf chunks (bounded file size)

Properties needed at 1000-node scale, realised here at container scale:

- **Atomicity**: writes go to ``<dir>/.tmp_step_X`` and are renamed into
  place only after every chunk file *and* the manifest are fsynced — a
  killed job never leaves a half checkpoint that restore could pick up.
- **Integrity**: the manifest records a crc32 per leaf; ``restore``
  verifies them by default, so a truncated or bit-flipped chunk raises
  :class:`CheckpointCorruptError` instead of silently resuming from
  garbage.
- **Degraded restore**: ``latest_step`` considers only *valid*
  candidates (a ``step_*`` dir with a parseable manifest — dangling
  ``.tmp_step_*`` dirs and manifest-less dirs are skipped, never
  crashed on), and ``restore(step=None)`` falls back newest-first
  through :func:`valid_steps`, quarantining corrupt dirs (renamed to
  ``.corrupt_step_*``) so later scans skip them.
- **Bounded retry**: ``save`` retries transient I/O failures with
  exponential backoff before giving up, cleaning its temp dir between
  attempts.
- **Restart**: ``latest_step``/``restore`` resume bit-exact (optimizer
  state, data cursor and RNG key live in the manifest).
- **Elasticity**: leaves are saved as *logical* (unsharded) arrays, so a
  restore may target any mesh/sharding — the caller passes target
  shardings and we ``jax.device_put`` per leaf. Changing (data, model)
  mesh shape between runs is therefore a restore-time concern only.
- **Async**: ``save_async`` snapshots to host memory synchronously (one
  device_get) and writes in a background thread, overlapping the next
  training steps; ``wait`` joins before the next save or exit.

A production deployment would swap the npz writer for per-host sharded
files + a distributed commit barrier; the manifest/atomic-rename
protocol — and the validity/quarantine scan — are unchanged.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zipfile
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "wait", "restore", "latest_step",
           "valid_steps", "CheckpointCorruptError"]

_MAX_CHUNK_BYTES = 1 << 30
_pending: list[threading.Thread] = []


class CheckpointCorruptError(RuntimeError):
    """A checkpoint dir exists but fails integrity checks (missing or
    truncated chunk files, crc32 mismatch, unreadable manifest)."""


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    else:
        yield "/".join(prefix), tree


def _unflatten(flat: dict[str, Any]):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, tree: dict, *, meta: dict | None = None,
         retries: int = 2, backoff: float = 0.05):
    """Synchronous atomic save of a pytree-of-arrays.

    Transient ``OSError`` during the write is retried up to ``retries``
    times with exponential backoff (the temp dir is removed between
    attempts so every attempt starts clean); the last failure re-raises.
    """
    host = {k: np.asarray(v) for k, v in _flatten(tree)}
    _write_with_retry(ckpt_dir, step, host, meta or {}, retries, backoff)


def save_async(ckpt_dir: str, step: int, tree: dict, *,
               meta: dict | None = None, retries: int = 2,
               backoff: float = 0.05):
    """Snapshot to host now; write (with the same bounded retry) in
    background."""
    host = {k: np.asarray(v) for k, v in _flatten(tree)}  # sync device->host
    t = threading.Thread(
        target=_write_with_retry,
        args=(ckpt_dir, step, host, meta or {}, retries, backoff),
        daemon=True)
    t.start()
    _pending.append(t)


def wait():
    while _pending:
        _pending.pop().join()


def _write_with_retry(ckpt_dir: str, step: int, host: dict, meta: dict,
                      retries: int, backoff: float):
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    for attempt in range(retries + 1):
        try:
            _write(ckpt_dir, step, host, meta)
            return
        except OSError:
            shutil.rmtree(tmp, ignore_errors=True)
            if attempt == retries:
                raise
            time.sleep(backoff * (2 ** attempt))


def _write(ckpt_dir: str, step: int, host: dict[str, np.ndarray], meta: dict):
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    # chunk leaves into bounded npz files
    chunks: list[dict[str, np.ndarray]] = [{}]
    size = 0
    index = {}
    for k, v in host.items():
        if size > _MAX_CHUNK_BYTES:
            chunks.append({})
            size = 0
        logical_dtype = str(v.dtype)
        if v.dtype.kind not in "biufc":  # e.g. ml_dtypes bfloat16: npz-unsafe
            v = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
        chunks[-1][k] = v
        index[k] = {"file": len(chunks) - 1, "shape": list(v.shape),
                    "dtype": logical_dtype,
                    "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes())}
        size += v.nbytes
    for i, c in enumerate(chunks):
        # npz keys cannot contain '/', escape; fsync each chunk so the
        # final rename publishes only fully-durable data files
        with open(os.path.join(tmp, f"arrays_{i:02d}.npz"), "wb") as f:
            np.savez(f, **{k.replace("/", "::"): v for k, v in c.items()})
            f.flush()
            os.fsync(f.fileno())
    manifest = {"step": step, "index": index, "meta": meta,
                "n_chunks": len(chunks)}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):  # overwrite-save of same step
        shutil.rmtree(final)
    os.rename(tmp, final)


def _read_manifest(d: str) -> dict | None:
    """The dir's manifest, or None when missing/unparseable (a partial
    or torn checkpoint — never an exception)."""
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError):
        return None


def valid_steps(ckpt_dir: str) -> list[int]:
    """Sorted steps of every *candidate* checkpoint: a ``step_*`` dir
    whose manifest parses. Dangling ``.tmp_step_*`` dirs, quarantined
    ``.corrupt_step_*`` dirs, manifest-less and torn-manifest dirs are
    all skipped (a crashed or interfering writer must never take
    restore down). Chunk contents are *not* verified here — that is
    restore's job (crc32 per leaf)."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_"):
            continue
        try:
            step = int(d.split("_")[1])
        except (IndexError, ValueError):
            continue
        if _read_manifest(os.path.join(ckpt_dir, d)) is not None:
            steps.append(step)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a readable manifest (None when there is none)."""
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def _quarantine(ckpt_dir: str, step: int) -> None:
    """Rename a corrupt ``step_*`` dir to ``.corrupt_step_*`` so later
    ``valid_steps`` scans skip it without re-verifying. Best-effort: a
    failed rename (e.g. read-only fs) must not mask the original
    corruption."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    dst = os.path.join(ckpt_dir, f".corrupt_step_{step:08d}")
    try:
        if os.path.exists(dst):
            shutil.rmtree(dst)
        os.rename(src, dst)
    except OSError:
        pass


def _load(d: str, verify: bool) -> tuple[dict, dict]:
    """Load one checkpoint dir -> (flat leaves, manifest). Raises
    CheckpointCorruptError on any integrity failure."""
    manifest = _read_manifest(d)
    if manifest is None:
        raise CheckpointCorruptError(f"missing/unreadable manifest in {d}")
    import ml_dtypes  # bundled with jax

    loaded: dict[str, np.ndarray] = {}
    index = manifest["index"]
    for i in range(manifest["n_chunks"]):
        path = os.path.join(d, f"arrays_{i:02d}.npz")
        try:
            with np.load(path) as z:
                for k in z.files:
                    key = k.replace("::", "/")
                    v = z[k]
                    loaded[key] = v
        except (OSError, ValueError, EOFError, zlib.error,
                zipfile.BadZipFile) as e:
            raise CheckpointCorruptError(
                f"unreadable chunk {path}: {e}") from e
    for key, entry in index.items():
        if key not in loaded:
            raise CheckpointCorruptError(f"leaf {key!r} missing from {d}")
        v = loaded[key]
        want_crc = entry.get("crc32")  # absent in pre-integrity checkpoints
        if verify and want_crc is not None:
            got = zlib.crc32(np.ascontiguousarray(v).tobytes())
            if got != want_crc:
                raise CheckpointCorruptError(
                    f"crc mismatch for leaf {key!r} in {d}: "
                    f"{got:#010x} != {want_crc:#010x}")
        want = entry["dtype"]
        if str(v.dtype) != want:  # un-view non-native dtypes
            v = v.view(np.dtype(getattr(ml_dtypes, want)))
        loaded[key] = v
    return loaded, manifest


def restore(ckpt_dir: str, step: int | None = None, *,
            shardings=None, verify: bool = True,
            quarantine: bool = True) -> tuple[dict, dict]:
    """Returns (tree, meta). ``shardings``: optional matching pytree of
    jax.sharding.Sharding — enables elastic restore onto a new mesh.

    ``verify`` (default on) checks every leaf against its manifest crc32.
    With ``step=None`` the newest valid checkpoint is tried first and
    corrupt/partial dirs **fall back** to the next older one (the dir is
    quarantined — renamed ``.corrupt_step_*`` — unless
    ``quarantine=False``); an explicit ``step`` raises
    :class:`CheckpointCorruptError` instead of falling back.
    """
    if step is not None:
        loaded, manifest = _load(
            os.path.join(ckpt_dir, f"step_{step:08d}"), verify)
        return _finish(loaded, manifest, shardings)
    last_err: Exception | None = None
    for cand in reversed(valid_steps(ckpt_dir)):
        try:
            loaded, manifest = _load(
                os.path.join(ckpt_dir, f"step_{cand:08d}"), verify)
            return _finish(loaded, manifest, shardings)
        except CheckpointCorruptError as e:
            last_err = e
            if quarantine:
                _quarantine(ckpt_dir, cand)
    if last_err is not None:
        raise FileNotFoundError(
            f"no restorable checkpoint under {ckpt_dir} "
            f"(newest failures: {last_err})")
    raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")


def _finish(loaded: dict, manifest: dict, shardings) -> tuple[dict, dict]:
    tree = _unflatten(loaded)
    if shardings is not None:
        flat_s = dict(_flatten(shardings))
        tree = _unflatten({
            k: jax.device_put(v, flat_s[k]) if k in flat_s else v
            for k, v in _flatten(tree)})
    return tree, manifest["meta"]
