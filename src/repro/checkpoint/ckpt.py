"""Fault-tolerant checkpointing: atomic, manifest-driven, elastic.

Layout of a checkpoint directory::

    <dir>/step_000123/          # finished checkpoints only (atomic rename)
        manifest.json           # step, data cursor, rng, tree structure,
                                # leaf shapes/dtypes, shard chunking
        arrays_00.npz ...       # leaf chunks (bounded file size)

Properties needed at 1000-node scale, realised here at container scale:

- **Atomicity**: writes go to ``<dir>/.tmp_step_X`` and are renamed into
  place only after fsync — a killed job never leaves a half checkpoint
  that restore could pick up.
- **Restart**: ``latest_step``/``restore`` resume bit-exact (optimizer
  state, data cursor and RNG key live in the manifest).
- **Elasticity**: leaves are saved as *logical* (unsharded) arrays, so a
  restore may target any mesh/sharding — the caller passes target
  shardings and we ``jax.device_put`` per leaf. Changing (data, model)
  mesh shape between runs is therefore a restore-time concern only.
- **Async**: ``save_async`` snapshots to host memory synchronously (one
  device_get) and writes in a background thread, overlapping the next
  training steps; ``wait`` joins before the next save or exit.

A production deployment would swap the npz writer for per-host sharded
files + a distributed commit barrier; the manifest/atomic-rename protocol
is unchanged.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "wait", "restore", "latest_step"]

_MAX_CHUNK_BYTES = 1 << 30
_pending: list[threading.Thread] = []


def _flatten(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], prefix + (str(k),))
    else:
        yield "/".join(prefix), tree


def _unflatten(flat: dict[str, Any]):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, tree: dict, *, meta: dict | None = None):
    """Synchronous atomic save of a pytree-of-arrays."""
    host = {k: np.asarray(v) for k, v in _flatten(tree)}
    _write(ckpt_dir, step, host, meta or {})


def save_async(ckpt_dir: str, step: int, tree: dict, *, meta: dict | None = None):
    """Snapshot to host now; write in background."""
    host = {k: np.asarray(v) for k, v in _flatten(tree)}  # sync device->host
    t = threading.Thread(target=_write, args=(ckpt_dir, step, host, meta or {}),
                         daemon=True)
    t.start()
    _pending.append(t)


def wait():
    while _pending:
        _pending.pop().join()


def _write(ckpt_dir: str, step: int, host: dict[str, np.ndarray], meta: dict):
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    # chunk leaves into bounded npz files
    chunks: list[dict[str, np.ndarray]] = [{}]
    size = 0
    index = {}
    for k, v in host.items():
        if size > _MAX_CHUNK_BYTES:
            chunks.append({})
            size = 0
        logical_dtype = str(v.dtype)
        if v.dtype.kind not in "biufc":  # e.g. ml_dtypes bfloat16: npz-unsafe
            v = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
        chunks[-1][k] = v
        index[k] = {"file": len(chunks) - 1, "shape": list(v.shape),
                    "dtype": logical_dtype}
        size += v.nbytes
    for i, c in enumerate(chunks):
        # npz keys cannot contain '/', escape
        np.savez(os.path.join(tmp, f"arrays_{i:02d}.npz"),
                 **{k.replace("/", "::"): v for k, v in c.items()})
    manifest = {"step": step, "index": index, "meta": meta,
                "n_chunks": len(chunks)}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):  # overwrite-save of same step
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int | None = None, *,
            shardings=None) -> tuple[dict, dict]:
    """Returns (tree, meta). ``shardings``: optional matching pytree of
    jax.sharding.Sharding — enables elastic restore onto a new mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes  # bundled with jax

    loaded: dict[str, np.ndarray] = {}
    index = manifest["index"]
    for i in range(manifest["n_chunks"]):
        with np.load(os.path.join(d, f"arrays_{i:02d}.npz")) as z:
            for k in z.files:
                key = k.replace("::", "/")
                v = z[k]
                want = index[key]["dtype"]
                if str(v.dtype) != want:  # un-view non-native dtypes
                    v = v.view(np.dtype(getattr(ml_dtypes, want)))
                loaded[key] = v
    tree = _unflatten(loaded)
    if shardings is not None:
        flat_s = dict(_flatten(shardings))
        tree = _unflatten({
            k: jax.device_put(v, flat_s[k]) if k in flat_s else v
            for k, v in _flatten(tree)})
    return tree, manifest["meta"]
