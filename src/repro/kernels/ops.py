"""Public jit'd wrappers around the Pallas kernels.

Every op has an exact pure-jnp fallback (ref.py) selected by
``use_kernel=False`` — the default model/stencil code paths run the
fallback on CPU (interpret-mode kernels are functionally identical but
slow), and flip to the kernels on TPU deployment via config.
"""

from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import PERIODIC
from repro.core.layout import blockize_with_halo, device_constant, unblockize
from repro.core.orderings import OrderingSpec
from repro.core.surfaces import surface_path_indices

from . import ref
from .flash_attn import flash_attention_fwd
from .sfc_gather import gather_rows
from .stencil3d import stencil_sum_blocks

__all__ = ["gol3d_step", "pack_surface", "unpack_surface",
           "flash_attention", "sfc_gather_take", "uniform_weights"]


def _build_uniform_weights(g: int) -> np.ndarray:
    s = 2 * g + 1
    w = np.ones((s, s, s), dtype=np.float32)
    w[g, g, g] = 0.0
    return w


def uniform_weights(g: int):
    """All-ones stencil with a zero centre (neighbour count).

    Cached device constant: repeated jits of the stencil pipelines reuse
    one buffer instead of re-uploading per trace.
    """
    return device_constant(("golw", g), lambda: _build_uniform_weights(g))


def _surface_idx_device(spec: OrderingSpec, M: int, g: int, face: str):
    """Cached device copy of a face's path-index list (int32)."""
    return device_constant(("surfidx", spec, M, g, face),
                           lambda: surface_path_indices(spec, M, g, face))


@functools.partial(jax.jit, static_argnames=("g", "block_kind", "T",
                                             "use_kernel", "bc", "interpret"))
def gol3d_step(cube: jnp.ndarray, *, g: int, T: int = 8,
               block_kind: str = "morton", use_kernel: bool = False,
               bc=PERIODIC, interpret: bool = True) -> jnp.ndarray:
    """One gol3d update via the SFC-blocked stencil pipeline.

    blockize_with_halo (SFC layout) → stencil kernel → rule → unblockize.
    Semantically identical to ref.gol3d_step_ref under the same ``bc``
    (core.boundary contract: periodic wrap, dirichlet constant, or
    neumann0 edge replication — the halo bake-in realises all three).
    """
    M = cube.shape[0]
    blocks = blockize_with_halo(cube, T, g, kind=block_kind, bc=bc)
    if use_kernel:
        neigh = stencil_sum_blocks(blocks, uniform_weights(g), g=g,
                                   interpret=interpret)
    else:
        neigh = ref.stencil_sum_ref(blocks, uniform_weights(g))
    centre = blocks[:, g:g + T, g:g + T, g:g + T]
    nxt = ref.gol_rule_ref(centre, neigh, g)
    return unblockize(nxt, M, kind=block_kind)


_ROW_PLANS: dict = {}
_ROW_PLANS_CAP = 256
# Same contract as layout._DEVICE_CONSTANTS_LOCK: the serving thread
# pool and the main trace thread share this LRU — mutate under the lock.
_ROW_PLANS_LOCK = threading.RLock()


def _row_plan(idx: np.ndarray, line: int, plan_key=None):
    """(unique rows covering idx, per-element position) — cached by key.

    The np.unique/searchsorted plan depends only on (idx, line); callers
    with a stable idx provenance (pack_surface: one face of one ordering)
    pass ``plan_key`` so repeated packs of the same face skip the O(|idx|
    log |idx|) host work. LRU-capped (and lock-guarded) like
    layout.device_constant; concurrent misses may both compute the plan
    (pure — benign), the dict is only touched under the lock.
    """
    key = None if plan_key is None else (plan_key, line)
    if key is not None:
        with _ROW_PLANS_LOCK:
            hit = _ROW_PLANS.get(key)
            if hit is not None:
                _ROW_PLANS[key] = _ROW_PLANS.pop(key)  # move-to-end
                return hit
    idx = np.asarray(idx)
    rows = np.unique(idx // line).astype(np.int32)
    pos = (np.searchsorted(rows, idx // line) * line + idx % line).astype(np.int32)
    rows.setflags(write=False)
    pos.setflags(write=False)
    if key is not None:  # numpy only — trace-safe to cache (cf. device_constant)
        with _ROW_PLANS_LOCK:
            while len(_ROW_PLANS) >= _ROW_PLANS_CAP:
                _ROW_PLANS.pop(next(iter(_ROW_PLANS)))
            _ROW_PLANS[key] = (rows, pos)
    return rows, pos


def sfc_gather_take(data: jnp.ndarray, idx: np.ndarray, *, line: int = 64,
                    use_kernel: bool = False, interpret: bool = True,
                    plan_key=None) -> jnp.ndarray:
    """data[idx] for a flat array, via line-granularity kernel gather.

    Kernel path: fetch the unique ``line``-sized rows covering ``idx``
    (one scalar-prefetched DMA each), then select elements. The row count
    is the modelled HBM traffic — SFC layouts need fewer rows (paper
    Figs 11/15 re-expressed). Exact for any idx. ``plan_key`` (hashable,
    identifying idx's provenance) memoises the row plan across calls.

    The fallback path gathers along the *last* axis, so a stacked
    multi-field ``(C, M³)`` state (DESIGN.md §9) packs all channels in
    one call; the kernel path stays 1-D (per-channel).
    """
    idx = np.asarray(idx)
    if not use_kernel:
        return jnp.take(data, jnp.asarray(idx), axis=-1)
    assert data.ndim == 1, "kernel gather path is 1-D (pack per channel)"
    n = data.shape[0]
    assert n % line == 0, (n, line)
    rows, pos = _row_plan(idx, line, plan_key)
    got = gather_rows(data.reshape(n // line, line), jnp.asarray(rows),
                      interpret=interpret)
    return got.reshape(-1)[jnp.asarray(pos)]


def pack_surface(data_path: jnp.ndarray, spec: OrderingSpec, M: int, g: int,
                 face: str, *, line: int = 64, use_kernel: bool = False,
                 interpret: bool = True) -> jnp.ndarray:
    """Pack one face of a path-ordered cube into a contiguous buffer.

    ``data_path`` is the (M³,) cube in ``spec`` order (apply_ordering) —
    or the stacked multi-field ``(C, M³)`` state (DESIGN.md §9), packed
    along the last axis so one call moves every channel's face. Buffer
    order is curve-visit order p_t (paper §3.2). The row plan is
    cached on (spec, M, g, face, line) across calls.

    ``g`` is the face *width* — the communication-avoiding distributed
    pipeline packs deep faces of width S·g (one exchange funds S fused
    substeps, stencil/halo.py), and packs them straight from the resident
    block store by passing ``layout.store_spec(kind, T)`` as the spec
    (the store is path-ordered state under that hybrid ordering).
    """
    idx = surface_path_indices(spec, M, g, face)
    return sfc_gather_take(data_path, idx, line=line, use_kernel=use_kernel,
                           interpret=interpret, plan_key=(spec, M, g, face))


def unpack_surface(data_path: jnp.ndarray, buf: jnp.ndarray,
                   spec: OrderingSpec, M: int, g: int, face: str) -> jnp.ndarray:
    """Inverse of pack_surface: scatter a buffer back into the cube."""
    return data_path.at[_surface_idx_device(spec, M, g, face)].set(buf)


# ----------------------------------------------------------------------
# Flash attention public API (GQA folding + trainable custom_vjp)
# ----------------------------------------------------------------------

def _fold_gqa(q, k, v):
    """(B,Hq,S,D)/(B,Hkv,S,D) -> (B*Hq, S, D) with kv repeated per group."""
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    return (q.reshape(B * Hq, Sq, D), k.reshape(B * Hq, -1, D),
            v.reshape(B * Hq, -1, D))


def _pick_block(s: int, pref: int) -> int:
    b = min(pref, s)
    while s % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, schedule: str = "morton",
                    block_q: int = 64, block_k: int = 64):
    """Trainable flash attention. q: (B,Hq,S,D); k,v: (B,Hkv,Sk,D).

    Forward runs the SFC-scheduled Pallas kernel; backward recomputes
    through the jnp oracle (standard recompute-bwd, keeps the kernel
    forward-only).
    """
    B, Hq, Sq, D = q.shape
    qf, kf, vf = _fold_gqa(q, k, v)
    bq = _pick_block(Sq, block_q)
    bk = _pick_block(kf.shape[1], block_k)
    o = flash_attention_fwd(qf, kf, vf, causal=causal, block_q=bq,
                            block_k=bk, schedule=schedule, interpret=True)
    return o.reshape(B, Hq, Sq, D)


def _fa_fwd(q, k, v, causal, schedule, block_q, block_k):
    return flash_attention(q, k, v, causal, schedule, block_q, block_k), (q, k, v)


def _fa_bwd(causal, schedule, block_q, block_k, res, g_out):
    q, k, v = res

    def ref_fn(q, k, v):
        B, Hq, Sq, D = q.shape
        qf, kf, vf = _fold_gqa(q, k, v)
        return ref.attention_ref(qf, kf, vf, causal=causal).reshape(B, Hq, Sq, D)

    _, vjp = jax.vjp(ref_fn, q, k, v)
    return vjp(g_out)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
