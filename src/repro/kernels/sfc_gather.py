"""Pallas TPU kernel: scalar-prefetched row gather (the pack primitive).

TPU-native form of the paper's precomputed-path-list buffer packing
(paper §4): the index list is a *scalar-prefetch* operand, so the TPU can
issue the HBM→VMEM DMA for row ``idx[i]`` ahead of grid step ``i`` — the
hardware analogue of "an initial traversal ... lists of path indices".

The gather granularity is a whole row of length L (one DMA). An SFC
layout makes face packing decompose into few long runs (core/surfaces.py
run stats), so rows are large and few; a row-major layout's slab-row
faces degrade to L=1 rows — the stride-M² pathology of Figs 11/15
re-expressed as DMA count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_rows"]


def _copy_kernel(idx_ref, x_ref, o_ref):
    del idx_ref  # consumed by the index_map
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(src: jnp.ndarray, idx: jnp.ndarray, *,
                interpret: bool = True) -> jnp.ndarray:
    """out[r] = src[idx[r]].  src: (N, L); idx: (R,) int32; out: (R, L)."""
    n, L = src.shape
    r = idx.shape[0]
    idx = idx.astype(jnp.int32)
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct((r, L), src.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(r,),
            in_specs=[pl.BlockSpec((1, L), lambda i, idx_ref: (idx_ref[i], 0))],
            out_specs=pl.BlockSpec((1, L), lambda i, idx_ref: (i, 0)),
        ),
        interpret=interpret,
    )(idx, src)
