"""Pallas TPU kernel: flash attention with space-filling-curve block schedule.

Beyond-paper application of the paper's idea (DESIGN.md §5, level 2): the
(q-block × kv-block) score grid of flash attention is a 2D index space.
Traversing it row-major re-streams every KV block for every q block; a
Morton/Hilbert traversal visits a 2×2 (then 4×4, …) neighbourhood of
blocks before moving on, so q-block and kv-block fetches are reused while
resident — the exact cache-line argument of the paper, with VMEM as the
cache and HBM→VMEM DMAs as the misses. benchmarks/kernel_bench.py scores
the schedules with the paper's own LRU model (core/cache_model).

Mechanics: one flat grid axis walks the (pre-filtered causal) cell list in
schedule order; the schedule is a trace-time numpy computation handed to
the kernel as scalar-prefetch operands, so the index maps (and hence the
DMA engine) know the next block ahead of time. Online-softmax statistics
are kept per q-row-block in VMEM scratch ``(nq, bq)``; the output tile is
rewritten on every visit (last visit wins), which keeps the kernel correct
under *any* traversal order. VMEM cost: ``nq·bq·(D+2)·4B`` — e.g. 4k
tokens, bq=128, D=128 → 2.1 MiB; for longer sequences the schedule is
applied hierarchically within VMEM-sized super-tiles (see ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.orderings import path_index_2d

__all__ = ["build_schedule", "flash_attention_fwd"]

_NEG_INF = float("-inf")


def build_schedule(nq: int, nk: int, *, causal: bool, block_q: int,
                   block_k: int, kind: str = "morton",
                   offs: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Cell visit order over the (nq × nk) block grid.

    Returns (iq_of_t, ik_of_t) int32 arrays of equal length = #visited
    cells. Causal filtering keeps cells whose block intersects
    ``col <= row + offs`` (offs = Sk - Sq aligns the diagonal at the end).
    """
    if kind == "row_major":
        cells = [(iq, ik) for iq in range(nq) for ik in range(nk)]
    else:
        n = 1 << max(0, (max(nq, nk) - 1)).bit_length()
        n = max(n, 2)
        seq = path_index_2d(kind, n)
        cells = [divmod(int(t), n) for t in seq]
        cells = [(iq, ik) for iq, ik in cells if iq < nq and ik < nk]
    if causal:
        cells = [(iq, ik) for iq, ik in cells
                 if ik * block_k <= (iq + 1) * block_q - 1 + offs]
    iq = np.array([c[0] for c in cells], dtype=np.int32)
    ik = np.array([c[1] for c in cells], dtype=np.int32)
    return iq, ik


def _flash_kernel(iq_ref, ik_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bq: int, bk: int, scale: float,
                  causal: bool, offs: int, out_dtype):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = iq_ref[t]
    ik = ik_ref[t]
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T) * scale  # (bq, bk) — MXU matmul
    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows + offs, s, _NEG_INF)

    m_prev = m_ref[iq]  # (bq,)
    l_prev = l_ref[iq]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    still_empty = m_cur == _NEG_INF  # rows with no unmasked key yet
    p = jnp.where(still_empty[:, None], 0.0, jnp.exp(s - m_cur[:, None]))
    alpha = jnp.where(still_empty, 1.0, jnp.exp(m_prev - m_cur))
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_new = acc_ref[iq] * alpha[:, None] + jnp.dot(p, v)
    m_ref[iq] = m_cur
    l_ref[iq] = l_new
    acc_ref[iq] = acc_new
    # rewrite the output tile each visit: correct under any schedule
    denom = jnp.where(l_new == 0.0, 1.0, l_new)
    o_ref[0] = (acc_new / denom[:, None]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "schedule", "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, block_q: int = 64,
                        block_k: int = 64, schedule: str = "morton",
                        interpret: bool = True) -> jnp.ndarray:
    """Flash attention forward. q: (BH, Sq, D); k, v: (BH, Sk, D).

    Heads are pre-folded into the batch axis (ops.py handles GQA).
    Sq/Sk must be divisible by block_q/block_k (ops.py picks blocks).
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    offs = Sk - Sq
    iq_arr, ik_arr = build_schedule(nq, nk, causal=causal, block_q=block_q,
                                    block_k=block_k, kind=schedule, offs=offs)
    ncells = len(iq_arr)
    kern = functools.partial(
        _flash_kernel, bq=block_q, bk=block_k, scale=1.0 / np.sqrt(D),
        causal=causal, offs=offs, out_dtype=q.dtype)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(BH, ncells),
            in_specs=[
                pl.BlockSpec((1, block_q, D), lambda b, t, iq, ik: (b, iq[t], 0)),
                pl.BlockSpec((1, block_k, D), lambda b, t, iq, ik: (b, ik[t], 0)),
                pl.BlockSpec((1, block_k, D), lambda b, t, iq, ik: (b, ik[t], 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, D),
                                   lambda b, t, iq, ik: (b, iq[t], 0)),
            scratch_shapes=[
                pltpu.VMEM((nq, block_q, D), jnp.float32),
                pltpu.VMEM((nq, block_q), jnp.float32),
                pltpu.VMEM((nq, block_q), jnp.float32),
            ],
        ),
        interpret=interpret,
    )(jnp.asarray(iq_arr), jnp.asarray(ik_arr), q, k, v)
