"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundary import PERIODIC, as_boundary, pad_cube

from .rules import apply_window_bc, get_rule

__all__ = ["stencil_sum_ref", "gol_rule_ref", "gol3d_step_ref",
           "assemble_halo_ref", "stencil_sum_resident_ref",
           "stencil_fused_ref", "fields_step_ref", "gather_rows_ref",
           "attention_ref"]


def stencil_sum_ref(blocks: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted (2g+1)³ stencil over halo-extended blocks.

    blocks:  (nb, T+2g, T+2g, T+2g)
    weights: (2g+1, 2g+1, 2g+1)
    returns: (nb, T, T, T) — acc[b, z] = sum_d w[d] * blocks[b, z+d]
    """
    s = weights.shape[0]
    g = (s - 1) // 2
    T = blocks.shape[1] - 2 * g
    acc = jnp.zeros((blocks.shape[0], T, T, T), dtype=jnp.float32)
    for dk in range(s):
        for di in range(s):
            for dj in range(s):
                acc = acc + weights[dk, di, dj].astype(jnp.float32) * (
                    blocks[:, dk:dk + T, di:di + T, dj:dj + T].astype(jnp.float32))
    return acc


def assemble_halo_ref(store: jnp.ndarray, nbr: jnp.ndarray, g: int) -> jnp.ndarray:
    """Resident halo assembly: gather each block's (T+2g)³ window from the
    un-haloed curve-ordered store via the SFC neighbour table.

    store: (nb_src, T, T, T) — or the stacked multi-field
    (C, nb_src, T, T, T) store (DESIGN.md §9), whose channels share the
    one neighbour table; nbr: (nb, 27) full table (core.neighbors),
    nb ≤ nb_src — the distributed extended store appends shell blocks
    after the core, so the table may index more blocks than it has rows;
    returns (nb, T+2g, T+2g, T+2g) (with the leading C kept for stacked
    input). With the periodic table of the same ordering this is
    bit-identical to layout.blockize_with_halo — the jnp oracle of the
    in-kernel assembly in stencil3d.stencil_sum_resident.
    """
    multi = store.ndim == 5
    T = store.shape[-3]
    assert g <= T, (g, T)
    nbr = jnp.asarray(nbr)
    lead = (slice(None),) if multi else ()
    own = store if store.shape[-4] == nbr.shape[0] \
        else store[lead + (slice(None, nbr.shape[0]),)]
    spans = (slice(T - g, T), slice(None), slice(0, g))  # lo, mid, hi
    slabs = []
    for a in range(3):
        planes = []
        for b in range(3):
            parts = []
            for c in range(3):
                col = a * 9 + b * 3 + c
                src = own if col == 13 \
                    else store[lead + (nbr[:, col],)]
                parts.append(src[lead + (slice(None), spans[a], spans[b],
                                         spans[c])])
            planes.append(jnp.concatenate(parts, axis=-1))
        slabs.append(jnp.concatenate(planes, axis=-2))
    return jnp.concatenate(slabs, axis=-3)


def stencil_sum_resident_ref(store: jnp.ndarray, weights: jnp.ndarray,
                             nbr: jnp.ndarray) -> jnp.ndarray:
    """Oracle for stencil3d.stencil_sum_resident (no halo store in HBM)."""
    g = (weights.shape[0] - 1) // 2
    return stencil_sum_ref(assemble_halo_ref(store, nbr, g), weights)


def stencil_fused_ref(store: jnp.ndarray, weights: jnp.ndarray,
                      nbr: jnp.ndarray, *, S: int = 1, rule: str = "gol",
                      bc=PERIODIC, bnd: jnp.ndarray | None = None) -> jnp.ndarray:
    """Oracle for stencil3d.stencil_step_fused: the temporal-blocked form.

    Assembles the wide (T+2·S·g)³ window once, then runs S substeps of
    tap-sum + rule with the window shrinking by g per side — the exact
    computation the fused kernel performs in VMEM, vectorised over nb.
    Bit-identical (f32 stores) to S sequential resident steps. Accepts
    the distributed extended store (shell blocks appended after the
    core, nbr rows = core only) like the kernel does, and the stacked
    multi-field ``(C, nb, T³)`` store (DESIGN.md §9): every substep
    tap-sums all C channels and hands the stacked fields to the rule,
    exactly as the kernel does.

    Clamped boundaries (DESIGN.md §8) mirror the kernel exactly: before
    every substep the ghost layers on faces flagged in ``bnd``
    ((nb, 6), core.neighbors.boundary_face_table column order) are
    substituted via rules.apply_window_bc — the same shared helper,
    applied per channel by broadcast.
    """
    g = (weights.shape[0] - 1) // 2
    bc = as_boundary(bc)
    r = get_rule(rule)
    if bc.clamped and bnd is None:
        raise ValueError(f"bc={bc.kind!r} needs the (nb, 6) bnd flag table")
    multi = store.ndim == 5
    C = store.shape[0] if multi else 1
    if C != r.channels:
        raise ValueError(
            f"rule {r.name!r} advances {r.channels} channel(s) but the store "
            f"carries {C} (shape {store.shape})")
    x = assemble_halo_ref(store, nbr, S * g).astype(jnp.float32)
    for u in range(S):
        x = apply_window_bc(x, jnp.asarray(bnd), g * (S - u), bc) \
            if bc.clamped else x
        if multi:
            tap = jnp.stack([stencil_sum_ref(x[c], weights) for c in range(C)])
            centre = x[:, :, g:-g, g:-g, g:-g]
        else:
            tap = stencil_sum_ref(x, weights)
            centre = x[:, g:-g, g:-g, g:-g]
        x = r.apply(centre, tap, g)
    return x.astype(store.dtype)


def fields_step_ref(fields: jnp.ndarray, weights: jnp.ndarray, g: int,
                    rule: str = "gol", bc=PERIODIC) -> jnp.ndarray:
    """One multi-field update on (C, M, M, M) canonical row-major fields.

    The ordering-independent sequential oracle of the C-channel stack
    (DESIGN.md §9): ghost-extend every channel under ``bc``
    (core.boundary.pad_cube — per-axis for mixed contracts), accumulate
    the weighted tap sum per channel **in the same dk,di,dj order as
    stencil_sum_ref** (so f32 results match the blocked paths bitwise,
    not just numerically), then apply the registry rule to the stacked
    fields. A 3-D input is treated as C=1 and returned 3-D.
    """
    r = get_rule(rule)
    squeeze = fields.ndim == 3
    if squeeze:
        fields = fields[None]
    C, M = fields.shape[0], fields.shape[1]
    assert fields.shape == (C, M, M, M), fields.shape
    if C != r.channels:
        raise ValueError(
            f"rule {r.name!r} advances {r.channels} channel(s), got {C}")
    s = weights.shape[0]
    assert s == 2 * g + 1, (weights.shape, g)
    xp = jnp.stack([pad_cube(fields[c], g, bc) for c in range(C)])
    tap = jnp.zeros((C, M, M, M), dtype=jnp.float32)
    for dk in range(s):
        for di in range(s):
            for dj in range(s):
                tap = tap + weights[dk, di, dj].astype(jnp.float32) * (
                    xp[:, dk:dk + M, di:di + M, dj:dj + M].astype(jnp.float32))
    out = r.apply(fields.astype(jnp.float32), tap, g).astype(fields.dtype)
    return out[0] if squeeze else out


def gol_rule_ref(state: jnp.ndarray, neigh_sum: jnp.ndarray, g: int) -> jnp.ndarray:
    """Generalised Game-of-Life rule (paper's gol3d, stencil radius g).

    Thresholds per rules.gol_thresholds — for g=1 (n=26): survive 6..9,
    born 9, a standard 3D GoL variant. Kept as the stable oracle entry
    point; the logic itself lives in the kernels/rules.py registry so
    the fused kernel shares it verbatim.
    """
    return get_rule("gol").apply(state, neigh_sum, g).astype(state.dtype)


def gol3d_step_ref(cube: jnp.ndarray, g: int, bc=PERIODIC) -> jnp.ndarray:
    """One gol3d update on an (M,M,M) cube in canonical row-major layout.

    ``bc`` is the boundary contract (core.boundary): the ghost extension
    is a wrap pad (periodic), a constant pad (dirichlet) or an edge-
    replication pad (neumann0) — the ordering-independent oracle every
    pipeline form is validated against, for every boundary kind.
    """
    s = 2 * g + 1
    xp = pad_cube(cube, g, bc)
    M = cube.shape[0]
    total = jnp.zeros_like(cube, dtype=jnp.float32)
    for dk in range(s):
        for di in range(s):
            for dj in range(s):
                total = total + xp[dk:dk + M, di:di + M, dj:dj + M].astype(jnp.float32)
    neigh = total - cube.astype(jnp.float32)  # exclude centre
    return gol_rule_ref(cube, neigh, g)


def gather_rows_ref(src: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """src: (N, L); idx: (R,) int32 -> (R, L)."""
    return jnp.take(src, idx, axis=0)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Dense softmax attention oracle. q,k,v: (BH, S, D) (heads pre-folded)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(d)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        # align causal diagonal to the END (supports Sk > Sq: decode w/ cache)
        offs = sk - sq
        mask = np.tril(np.ones((sq, sk), dtype=bool), k=offs)
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
