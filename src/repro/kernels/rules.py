"""Update-rule registry + boundary tap substitution (DESIGN.md §4, §8, §9).

The temporal-blocked kernel (stencil3d.stencil_step_fused) applies
``fields' = rule(fields, tap_sums)`` after every in-VMEM tap sum, so the
rule is the only workload-specific piece of the pipeline. Registering it
here — one pure-jnp callable shared verbatim by the Pallas kernel, the
jnp oracles (kernels/ref.py) and the fused driver
(stencil/pipeline.ResidentPipeline) — keeps the three paths bit-identical
by construction and lets a new workload ride the whole resident
machinery by adding one entry.

Multi-field contract (DESIGN.md §9): a rule declares ``channels`` (C)
and its ``apply(fields_f32, tap_sums_f32, g)`` receives the C state
fields *stacked on a leading axis* — ``(C, ...)`` where ``...`` is the
spatial window in the kernel, ``(nb, ...)`` in the batched oracles, or
the canonical cube in the global reference — together with the weighted
tap sum of **every** channel, and returns the next stacked fields. The
classic C=1 rules (gol, jacobi, identity) are elementwise, so the same
callables serve the stacked form bit-identically; ``wave`` (C=2) is the
FDTD-style leapfrog workload that actually couples channels.

Rules compute in float32 (the kernels' accumulation dtype); callers cast
back to the store dtype at the step boundary. ``tap_sums`` is the
weighted (2g+1)³ tap sum of the *current* state per channel — with the
default zero-centre uniform weights (ops.uniform_weights) it is the
neighbour count/sum the classic rules expect.

:func:`apply_window_bc` is the rules' boundary companion (DESIGN.md §8):
on clamped runs every substep's tap sum must read *boundary* values —
not wrapped or stale data — from the ghost sites outside the physical
domain, so the kernel and the oracles call this one helper to substitute
them before each tap sum. Like the rules themselves it is a single
pure-jnp definition shared verbatim by the Pallas kernel (per-window,
scalar flags from the prefetch channel) and the batched jnp oracles,
which is what keeps fused-vs-sequential clamped runs bit-identical.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.boundary import BoundarySpec, MixedBoundary, as_boundary

__all__ = ["UpdateRule", "RULES", "get_rule", "gol_thresholds",
           "WAVE_KAPPA", "apply_window_bc"]


@dataclass(frozen=True)
class UpdateRule:
    """name: registry key; apply(fields_f32, tap_sums_f32, g) -> next_f32.

    ``channels`` (C) is the number of state fields the rule advances;
    ``apply`` sees them stacked on the leading axis (C=1 rules are
    elementwise and accept any shape unchanged). The store a rule rides
    is ``(C, nb, T, T, T)`` — one shared block permutation, C channels
    (DESIGN.md §9).
    """
    name: str
    apply: Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray]
    doc: str = ""
    channels: int = 1


def gol_thresholds(g: int) -> tuple[int, int, int]:
    """(survive_lo, survive_hi, born) for the generalised GoL rule.

    With n = (2g+1)³ - 1 neighbours, thresholds scale with the classic
    2D 8-neighbour rule: survive in [2,3]·n/8, born at exactly round(3n/8).
    For g=1 (n=26): survive 6..9, born 9 — a standard 3D GoL variant.
    """
    n = (2 * g + 1) ** 3 - 1
    lo = (2 * n) // 8
    hi = (3 * n) // 8
    return lo, hi, hi


def _gol(centre: jnp.ndarray, tap: jnp.ndarray, g: int) -> jnp.ndarray:
    lo, hi, born = gol_thresholds(g)
    alive = centre > 0.5
    nxt = jnp.where(alive, (tap >= lo) & (tap <= hi), tap == born)
    return nxt.astype(jnp.float32)


def _jacobi(centre: jnp.ndarray, tap: jnp.ndarray, g: int) -> jnp.ndarray:
    # Jacobi relaxation / explicit heat step: box-filter mean over the
    # (2g+1)³ cube (centre + the zero-centre-weighted neighbour sum).
    n = (2 * g + 1) ** 3 - 1
    return (centre + tap) / jnp.float32(n + 1)


def _identity(centre: jnp.ndarray, tap: jnp.ndarray, g: int) -> jnp.ndarray:
    return tap


# Courant-like coupling of the wave leapfrog. A power of two, so the
# κ·lap product is an *exact* f32 scaling — FMA contraction of
# ``v + κ·lap`` cannot shift the rounding between compiled programs —
# and small enough that κ·λ_max < 4 for the 26-neighbour Laplacian
# (λ_max ≤ 2n with n = 26): the leapfrog stays stable, state bounded.
WAVE_KAPPA = 0.03125  # 2**-5


def _wave(fields: jnp.ndarray, taps: jnp.ndarray, g: int) -> jnp.ndarray:
    """FDTD-style 2-field wave leapfrog (DESIGN.md §9): u is the
    displacement, v the velocity. The Laplacian comes from the uniform
    zero-centre tap sum: lap u = Σ_neigh u - n·u; then

        v' = v + κ · lap u        (kick)
        u' = u + v'               (drift)

    — symplectic Euler on the semi-discrete wave equation. v's tap sum
    arrives (the kernel computes all C channels, the ×C bytes model
    counts it) but the rule does not consume it.

    ``n·u`` is subtracted as a sum of power-of-two multiples (16u, 8u,
    2u for g=1): every product is an exact f32 scaling, so XLA's FMA
    contraction cannot shift a rounding between compiled programs and
    the rule stays bit-identical across every pipeline form — the same
    reproducibility contract the integer-valued gol rule gets for free.
    """
    n = (2 * g + 1) ** 3 - 1
    u, v = fields[0], fields[1]
    lap = taps[0]
    bit = 1 << (n.bit_length() - 1)
    rem = n
    while bit:
        if rem >= bit:
            lap = lap - jnp.float32(bit) * u
            rem -= bit
        bit >>= 1
    v2 = v + jnp.float32(WAVE_KAPPA) * lap
    u2 = u + v2
    return jnp.stack([u2, v2])


RULES: dict[str, UpdateRule] = {
    "gol": UpdateRule("gol", _gol, "generalised 3D Game of Life (paper §4)"),
    "jacobi": UpdateRule("jacobi", _jacobi, "Jacobi/heat box-filter relaxation"),
    "identity": UpdateRule("identity", _identity, "raw weighted stencil sum"),
    "wave": UpdateRule("wave", _wave,
                       "FDTD-style 2-field wave leapfrog (u, v)", channels=2),
}


def _plane(x: jnp.ndarray, axis: int, i: int) -> jnp.ndarray:
    """Size-1 static slice at index ``i`` along one of the last 3 axes."""
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(i, i + 1)
    return x[tuple(idx)]


def apply_window_bc(x: jnp.ndarray, flags, depth: int,
                    bc: BoundarySpec | MixedBoundary | str) -> jnp.ndarray:
    """Substitute boundary values into a window's ghost layers.

    x:      a stencil window whose last three axes span the spatial
            extent — ``(E, E, E)`` or ``(C, E, E, E)`` inside the fused
            kernel, ``(nb, E, E, E)`` / ``(C, nb, E, E, E)`` in the
            batched jnp oracles. All leading axes (channels, blocks)
            broadcast: the contract applies to every channel alike.
    flags:  which of the window's six faces are clamped *domain* faces,
            in ``core.neighbors.OFFSETS_FACE`` order [k-,k+,i-,i+,j-,j+]
            — a ``(6,)``/``(nb, 6)`` int array, or a sequence of six
            scalars (the kernel reads them off the scalar-prefetch ref).
    depth:  ghost width to refresh: the outer ``depth`` layers of each
            flagged face are outside the physical domain.
    bc:     the contract (core.boundary): dirichlet writes the constant,
            neumann0 replicates the adjacent domain-edge plane; periodic
            is a no-op (ghost data arrives by wrap/exchange instead). A
            ``MixedBoundary`` applies its own spec per axis — periodic
            axes are skipped entirely, so their ghost layers keep the
            wrapped/exchanged data.

    Axes are refreshed sequentially (k, then i, then j) so corner ghost
    regions compose exactly like ``jnp.pad``'s per-axis semantics — the
    invariant that keeps every pipeline form equal to the padded-cube
    oracle (ref.gol3d_step_ref). The fused kernel calls this before
    *every* substep with the shrinking ghost depth ``g·(S-u)``
    (DESIGN.md §8): the refresh re-derives ghost layers from the current
    in-window state, which is what lets clamped faces temporally block
    as deep as periodic ones.
    """
    bc = as_boundary(bc)
    if not bc.clamped or depth == 0:
        return x
    E = x.shape[-1]
    batch = x.ndim > 3

    def flag(col):
        if isinstance(flags, (list, tuple)):
            f = flags[col] != 0
        else:
            f = flags[..., col] != 0
        return f[..., None, None, None] if batch else f

    for ax in range(3):
        ax_bc = bc.axes[ax]
        if not ax_bc.clamped:
            continue
        axis = ax - 3
        iota = jax.lax.broadcasted_iota(jnp.int32, x.shape[-3:], ax)
        if ax_bc.kind == "dirichlet":
            lo_fill = hi_fill = jnp.asarray(ax_bc.value, x.dtype)
        else:  # neumann0: replicate the nearest in-domain plane
            lo_fill = _plane(x, axis, depth)
            hi_fill = _plane(x, axis, E - 1 - depth)
        x = jnp.where((iota < depth) & flag(2 * ax), lo_fill, x)
        x = jnp.where((iota >= E - depth) & flag(2 * ax + 1), hi_fill, x)
    return x


def get_rule(rule: str | UpdateRule) -> UpdateRule:
    if isinstance(rule, UpdateRule):
        return rule
    try:
        return RULES[rule]
    except KeyError:
        raise ValueError(
            f"unknown update rule {rule!r}; known: {sorted(RULES)}") from None
