"""Update-rule registry for the fused stencil epilogue (DESIGN.md §4).

The temporal-blocked kernel (stencil3d.stencil_step_fused) applies
``state' = rule(state, tap_sum)`` after every in-VMEM tap sum, so the
rule is the only workload-specific piece of the pipeline. Registering it
here — one pure-jnp callable shared verbatim by the Pallas kernel, the
jnp oracles (kernels/ref.py) and the fused driver
(stencil/pipeline.ResidentPipeline) — keeps the three paths bit-identical
by construction and lets a new workload ride the whole resident
machinery by adding one entry.

Rules compute in float32 (the kernels' accumulation dtype); callers cast
back to the store dtype at the step boundary. ``tap_sum`` is the
weighted (2g+1)³ tap sum of the *current* state — with the default
zero-centre uniform weights (ops.uniform_weights) it is the neighbour
count/sum the classic rules expect.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

__all__ = ["UpdateRule", "RULES", "get_rule", "gol_thresholds"]


@dataclass(frozen=True)
class UpdateRule:
    """name: registry key; apply(centre_f32, tap_sum_f32, g) -> next_f32."""
    name: str
    apply: Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray]
    doc: str = ""


def gol_thresholds(g: int) -> tuple[int, int, int]:
    """(survive_lo, survive_hi, born) for the generalised GoL rule.

    With n = (2g+1)³ - 1 neighbours, thresholds scale with the classic
    2D 8-neighbour rule: survive in [2,3]·n/8, born at exactly round(3n/8).
    For g=1 (n=26): survive 6..9, born 9 — a standard 3D GoL variant.
    """
    n = (2 * g + 1) ** 3 - 1
    lo = (2 * n) // 8
    hi = (3 * n) // 8
    return lo, hi, hi


def _gol(centre: jnp.ndarray, tap: jnp.ndarray, g: int) -> jnp.ndarray:
    lo, hi, born = gol_thresholds(g)
    alive = centre > 0.5
    nxt = jnp.where(alive, (tap >= lo) & (tap <= hi), tap == born)
    return nxt.astype(jnp.float32)


def _jacobi(centre: jnp.ndarray, tap: jnp.ndarray, g: int) -> jnp.ndarray:
    # Jacobi relaxation / explicit heat step: box-filter mean over the
    # (2g+1)³ cube (centre + the zero-centre-weighted neighbour sum).
    n = (2 * g + 1) ** 3 - 1
    return (centre + tap) / jnp.float32(n + 1)


def _identity(centre: jnp.ndarray, tap: jnp.ndarray, g: int) -> jnp.ndarray:
    return tap


RULES: dict[str, UpdateRule] = {
    "gol": UpdateRule("gol", _gol, "generalised 3D Game of Life (paper §4)"),
    "jacobi": UpdateRule("jacobi", _jacobi, "Jacobi/heat box-filter relaxation"),
    "identity": UpdateRule("identity", _identity, "raw weighted stencil sum"),
}


def get_rule(rule: str | UpdateRule) -> UpdateRule:
    if isinstance(rule, UpdateRule):
        return rule
    try:
        return RULES[rule]
    except KeyError:
        raise ValueError(
            f"unknown update rule {rule!r}; known: {sorted(RULES)}") from None
