"""Pallas TPU kernels (interpret-validated) + jnp oracles.

- stencil3d.py  — SFC-blocked 3D weighted stencil (paper's compute loop),
                  incl. the fused temporal-blocked resident form
- rules.py      — update-rule registry shared by kernels and oracles
- sfc_gather.py — scalar-prefetched row gather (paper's pack primitive)
- flash_attn.py — flash attention with Morton/Hilbert block schedule
- ops.py        — public jit'd wrappers (kernel or jnp-ref selectable)
- ref.py        — pure-jnp oracles
"""

from .ops import (  # noqa: F401
    gol3d_step, pack_surface, unpack_surface, flash_attention, sfc_gather_take,
    uniform_weights,
)
from .rules import RULES, UpdateRule, get_rule  # noqa: F401
from .stencil3d import (  # noqa: F401
    stencil_step_fused, stencil_sum_blocks, stencil_sum_resident,
)
from .sfc_gather import gather_rows  # noqa: F401
from .flash_attn import flash_attention_fwd, build_schedule  # noqa: F401
