"""Pallas TPU kernels: SFC-blocked 3D weighted stencil (DESIGN.md §2–§3).

Two forms of the paper's layout insight:

``stencil_sum_blocks`` — the original *repack* form: the cube is stored
as ``(nb, T+2g, T+2g, T+2g)`` halo-extended blocks whose order in HBM
follows a space-filling curve (core/layout.blockize_with_halo). One grid
step = one block: load the ``(T+2g)³`` window into VMEM, produce a ``T³``
tile. Simple, but the halo store duplicates HBM by ``((T+2g)/T)³`` and
must be rebuilt from the canonical cube every step — an O(M³) gather
that swamps the kernel's contiguous-walk advantage (DESIGN.md §3).

``stencil_sum_resident`` — the *resident* form: the store is the
un-haloed ``(nb, T, T, T)`` block array that persists across timesteps,
and the halo is assembled **inside the kernel**. A precomputed SFC
neighbour table (core/neighbors.py) rides the scalar-prefetch channel —
the same mechanism as kernels/sfc_gather.py — so the index map of grid
step ``i`` can point each of the 27 window pieces (6 faces, 12 edges,
8 corners, 1 centre) at the right slice of the right neighbour block.
The HBM read per step is exactly ``(T+2g)³`` per block with *no* halo
store in HBM and *no* per-step repack; because blocks are curve-ordered,
consecutive grid steps ask for overlapping neighbour sets, which Pallas'
revisiting-block elision turns into VMEM reuse.

VMEM budget: ``4B·((T+2g)³ + T³ + (2g+1)³)`` — e.g. T=32, g=1 → ~290 KiB,
far under the ~16 MiB/core budget, leaving room for Pallas' double
buffering of the streamed blocks.  MXU note: a pure stencil is VPU work
(elementwise FMA); both kernels unroll the (2g+1)³ taps for g ≤ 2 so the
adds pipeline, and fall back to a ``fori_loop`` for larger g to bound
code size. Production layouts would pad the minor dim to the 128-lane
register width; correctness here is validated in interpret mode against
ref.stencil_sum_ref / ref.stencil_sum_resident_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["stencil_sum_blocks", "stencil_sum_resident"]

_UNROLL_TAP_LIMIT = 125  # unroll (2g+1)^3 taps up to g=2


def _tap_sum(x: jnp.ndarray, w_ref, T: int, s: int) -> jnp.ndarray:
    """acc[z] = sum_d w[d] * x[z+d] over the (s,s,s) taps; x: (T+s-1,)³."""
    if s ** 3 <= _UNROLL_TAP_LIMIT:
        acc = jnp.zeros((T, T, T), dtype=jnp.float32)
        for dk in range(s):
            for di in range(s):
                for dj in range(s):
                    acc = acc + w_ref[dk, di, dj].astype(jnp.float32) * (
                        x[dk:dk + T, di:di + T, dj:dj + T])
        return acc

    def body(t, acc):
        dk = t // (s * s)
        di = (t // s) % s
        dj = t % s
        win = jax.lax.dynamic_slice(x, (dk, di, dj), (T, T, T))
        return acc + w_ref[dk, di, dj].astype(jnp.float32) * win

    return jax.lax.fori_loop(0, s * s * s, body,
                             jnp.zeros((T, T, T), dtype=jnp.float32))


# ---------------------------------------------------------------- repack form

def _halo_kernel(w_ref, x_ref, o_ref, *, T: int, s: int):
    o_ref[0] = _tap_sum(x_ref[0].astype(jnp.float32), w_ref, T, s)


@functools.partial(jax.jit, static_argnames=("g", "interpret"))
def stencil_sum_blocks(blocks: jnp.ndarray, weights: jnp.ndarray, *,
                       g: int, interpret: bool = True) -> jnp.ndarray:
    """acc[b] = sum_d w[d] * blocks[b, z+d] for every block b.

    blocks:  (nb, T+2g, T+2g, T+2g)  — SFC-ordered, halo-extended
    weights: (2g+1, 2g+1, 2g+1)
    returns: (nb, T, T, T) float32
    """
    nb, W = blocks.shape[0], blocks.shape[1]
    s = 2 * g + 1
    T = W - 2 * g
    assert weights.shape == (s, s, s), (weights.shape, s)
    kern = functools.partial(_halo_kernel, T=T, s=s)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((nb, T, T, T), jnp.float32),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((s, s, s), lambda i: (0, 0, 0)),        # weights: resident
            pl.BlockSpec((1, W, W, W), lambda i: (i, 0, 0, 0)),  # one block/step
        ],
        out_specs=pl.BlockSpec((1, T, T, T), lambda i: (i, 0, 0, 0)),
        interpret=interpret,
    )(weights, blocks)


# -------------------------------------------------------------- resident form

def _resident_kernel(nbr_ref, w_ref, *refs, T: int, s: int):
    """Assemble the (T+2g)³ window from 27 neighbour slices, then tap-sum.

    refs = 27 piece refs (in OFFSETS_FULL order) + the output ref. Piece
    (a,b,c) has shape (1, sz[a], sz[b], sz[c]) with sz = (g, T, g): low
    halo, centre span, high halo along each axis.
    """
    o_ref = refs[-1]
    pieces = [r[0].astype(jnp.float32) for r in refs[:-1]]
    slabs = []
    n = 0
    for _a in range(3):
        planes = []
        for _b in range(3):
            planes.append(jnp.concatenate(pieces[n:n + 3], axis=2))
            n += 3
        slabs.append(jnp.concatenate(planes, axis=1))
    x = jnp.concatenate(slabs, axis=0)  # (T+2g, T+2g, T+2g)
    o_ref[0] = _tap_sum(x, w_ref, T, s)


def _piece_index(i, nbr_ref, *, col: int, bidx: tuple):
    # nbr_ref[i, col] is the path position of the neighbour block this
    # piece is sliced from; bidx addresses the slice in block-shape units.
    return (nbr_ref[i, col],) + bidx


@functools.partial(jax.jit, static_argnames=("g", "interpret"))
def stencil_sum_resident(store: jnp.ndarray, weights: jnp.ndarray,
                         nbr: jnp.ndarray, *, g: int,
                         interpret: bool = True) -> jnp.ndarray:
    """In-kernel halo streaming over the persistent block store.

    store:   (nb, T, T, T)  — SFC-ordered, *no* halo duplication
    weights: (2g+1, 2g+1, 2g+1)
    nbr:     (nb, 27) int32 — full periodic neighbour table of the same
             ordering (core.neighbors.neighbor_table), scalar-prefetched
    returns: (nb, T, T, T) float32, bit-identical to
             stencil_sum_blocks(blockize_with_halo(...), ...)

    Halo pieces are addressed in block-shape units, so g must divide T
    (g ∈ {1, 2, 4, ...} for T = 8; use the repack form otherwise).
    """
    nb, T = store.shape[0], store.shape[1]
    s = 2 * g + 1
    assert store.shape == (nb, T, T, T), store.shape
    assert weights.shape == (s, s, s), (weights.shape, s)
    assert nbr.shape == (nb, 27), nbr.shape
    if g > T or T % g:
        raise ValueError(f"resident kernel needs g | T, got T={T}, g={g}")

    sz = (g, T, g)                 # piece extent per axis: lo, mid, hi
    last = (T // g - 1, 0, 0)      # block index of the slice: lo reads the
    #                                neighbour's *last* g-slab, mid/hi its first
    in_specs = [pl.BlockSpec((s, s, s), lambda i, nbr_ref: (0, 0, 0))]
    for a in range(3):
        for b in range(3):
            for c in range(3):
                col = a * 9 + b * 3 + c
                in_specs.append(pl.BlockSpec(
                    (1, sz[a], sz[b], sz[c]),
                    functools.partial(_piece_index, col=col,
                                      bidx=(last[a], last[b], last[c]))))
    kern = functools.partial(_resident_kernel, T=T, s=s)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((nb, T, T, T), jnp.float32),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, T, T, T), lambda i, nbr_ref: (i, 0, 0, 0)),
        ),
        interpret=interpret,
    )(nbr.astype(jnp.int32), weights, *([store] * 27))
