"""Pallas TPU kernels: SFC-blocked 3D weighted stencil (DESIGN.md §2–§3).

Two forms of the paper's layout insight:

``stencil_sum_blocks`` — the original *repack* form: the cube is stored
as ``(nb, T+2g, T+2g, T+2g)`` halo-extended blocks whose order in HBM
follows a space-filling curve (core/layout.blockize_with_halo). One grid
step = one block: load the ``(T+2g)³`` window into VMEM, produce a ``T³``
tile. Simple, but the halo store duplicates HBM by ``((T+2g)/T)³`` and
must be rebuilt from the canonical cube every step — an O(M³) gather
that swamps the kernel's contiguous-walk advantage (DESIGN.md §3).

``stencil_sum_resident`` — the *resident* form: the store is the
un-haloed ``(nb, T, T, T)`` block array that persists across timesteps,
and the halo is assembled **inside the kernel**. A precomputed SFC
neighbour table (core/neighbors.py) rides the scalar-prefetch channel —
the same mechanism as kernels/sfc_gather.py — so the index map of grid
step ``i`` can point each of the 27 window pieces (6 faces, 12 edges,
8 corners, 1 centre) at the right slice of the right neighbour block.
The HBM read per step is exactly ``(T+2g)³`` per block with *no* halo
store in HBM and *no* per-step repack; because blocks are curve-ordered,
consecutive grid steps ask for overlapping neighbour sets, which Pallas'
revisiting-block elision turns into VMEM reuse.

``stencil_step_fused`` — the *temporal-blocked* form (DESIGN.md §4): the
resident kernel above still writes an f32 tap-sum array to HBM and
leaves the update rule to a second pass. This kernel fuses the rule
epilogue (kernels/rules.py) into the launch and runs ``S`` whole
substeps per HBM round-trip: assemble a ``(T+2·S·g)³`` window from
neighbour slices of extent ``S·g``, then alternate tap-sum + rule in
VMEM with the window shrinking by ``g`` per side each substep, and
write the next ``T³`` state tile once. K timesteps cost ``ceil(K/S)``
launches; per substep the HBM stream drops from
``(T+2g)³ + 3·T³`` (resident + rule pass) to
``((T+2·S·g)³ + T³)/S`` — the locality-for-bandwidth trade of
Reissmann & Jahre, paid for with redundant boundary flops.

Boundary contract (DESIGN.md §8): ``stencil_step_fused`` takes a
``core.boundary`` contract (uniform or per-axis mixed) plus a second
scalar-prefetched ``(nb, 6)`` table of per-block clamped-face flags;
before every substep the flagged ghost layers are substituted with
boundary values (rules.apply_window_bc), so physical domains temporally
block exactly as deep as periodic ones.
``stencil_sum_blocks``/``stencil_sum_resident`` stay periodic-only
baselines (the repack form realises clamped runs by padding at blockize
time instead).

Multi-field stores (DESIGN.md §9): a rule that declares C > 1 channels
(``wave``) rides the stacked ``(C, nb, T³)`` store — the 27 piece specs
gain a whole-store channel dimension, one grid step assembles C windows,
tap-sums every channel, applies the rule to the stacked fields, and
writes C tiles. C=1 stores keep the original 4-D kernel program
byte-for-byte (bit-identity of the scalar rules to their pre-§9 runs is
load-bearing: XLA's contraction choices shift with rank).

VMEM budget: ``4B·(2·(T+2Sg)³ + 2·T³ + (2g+1)³)`` — e.g. T=8, g=1, S=4
→ ~37 KiB; the ``plan()`` autotuner in stencil/pipeline.py picks (T, S)
against the ~16 MiB/core budget. MXU note: a pure stencil is VPU work
(elementwise FMA); the kernels unroll the (2g+1)³ taps for g ≤ 2 so the
adds pipeline, and fall back to a ``fori_loop`` for larger g to bound
code size. Production layouts would pad the minor dim to the 128-lane
register width; correctness here is validated in interpret mode against
ref.stencil_sum_ref / ref.stencil_sum_resident_ref / ref.stencil_fused_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.boundary import (PERIODIC, BoundarySpec, MixedBoundary,
                                 as_boundary)

from .rules import apply_window_bc, get_rule

__all__ = ["stencil_sum_blocks", "stencil_sum_resident", "stencil_step_fused"]

_UNROLL_TAP_LIMIT = 125  # unroll (2g+1)^3 taps up to g=2


def _tap_sum(x: jnp.ndarray, w_ref, T: int, s: int) -> jnp.ndarray:
    """acc[z] = sum_d w[d] * x[z+d] over the (s,s,s) taps; x: (T+s-1,)³."""
    if s ** 3 <= _UNROLL_TAP_LIMIT:
        acc = jnp.zeros((T, T, T), dtype=jnp.float32)
        for dk in range(s):
            for di in range(s):
                for dj in range(s):
                    acc = acc + w_ref[dk, di, dj].astype(jnp.float32) * (
                        x[dk:dk + T, di:di + T, dj:dj + T])
        return acc

    def body(t, acc):
        dk = t // (s * s)
        di = (t // s) % s
        dj = t % s
        win = jax.lax.dynamic_slice(x, (dk, di, dj), (T, T, T))
        return acc + w_ref[dk, di, dj].astype(jnp.float32) * win

    return jax.lax.fori_loop(0, s * s * s, body,
                             jnp.zeros((T, T, T), dtype=jnp.float32))


# ---------------------------------------------------------------- repack form

def _halo_kernel(w_ref, x_ref, o_ref, *, T: int, s: int):
    o_ref[0] = _tap_sum(x_ref[0].astype(jnp.float32), w_ref, T, s)


@functools.partial(jax.jit, static_argnames=("g", "interpret"))
def stencil_sum_blocks(blocks: jnp.ndarray, weights: jnp.ndarray, *,
                       g: int, interpret: bool = True) -> jnp.ndarray:
    """acc[b] = sum_d w[d] * blocks[b, z+d] for every block b.

    blocks:  (nb, T+2g, T+2g, T+2g)  — SFC-ordered, halo-extended
    weights: (2g+1, 2g+1, 2g+1)
    returns: (nb, T, T, T) float32
    """
    nb, W = blocks.shape[0], blocks.shape[1]
    s = 2 * g + 1
    T = W - 2 * g
    assert weights.shape == (s, s, s), (weights.shape, s)
    kern = functools.partial(_halo_kernel, T=T, s=s)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((nb, T, T, T), jnp.float32),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((s, s, s), lambda i: (0, 0, 0)),        # weights: resident
            pl.BlockSpec((1, W, W, W), lambda i: (i, 0, 0, 0)),  # one block/step
        ],
        out_specs=pl.BlockSpec((1, T, T, T), lambda i: (i, 0, 0, 0)),
        interpret=interpret,
    )(weights, blocks)


# -------------------------------------------------------------- resident form

def _assemble_window(refs) -> jnp.ndarray:
    """Concatenate 27 piece refs (OFFSETS_FULL order) into one f32 window.

    Piece (a,b,c) has shape (1, sz[a], sz[b], sz[c]) with sz = (h, T, h)
    — or ``(C, 1, sz[a], sz[b], sz[c])`` in the multi-field store, where
    the leading channel axis rides along (DESIGN.md §9): low halo, centre
    span, high halo along each axis (h = halo width). Returns
    ``(T+2h,)³`` or ``(C, (T+2h)³…)`` accordingly — concatenation is on
    the last three (spatial) axes either way.
    """
    pieces = [(r[0] if len(r.shape) == 4 else r[:, 0]).astype(jnp.float32)
              for r in refs]
    slabs = []
    n = 0
    for _a in range(3):
        planes = []
        for _b in range(3):
            planes.append(jnp.concatenate(pieces[n:n + 3], axis=-1))
            n += 3
        slabs.append(jnp.concatenate(planes, axis=-2))
    return jnp.concatenate(slabs, axis=-3)


def _resident_kernel(nbr_ref, w_ref, *refs, T: int, s: int):
    """Assemble the (T+2g)³ window from 27 neighbour slices, then tap-sum."""
    o_ref = refs[-1]
    x = _assemble_window(refs[:-1])
    o_ref[0] = _tap_sum(x, w_ref, T, s)


def _piece_index(i, nbr_ref, *_extra_prefetch, col: int, bidx: tuple,
                 channels: bool = False):
    # nbr_ref[i, col] is the path position of the neighbour block this
    # piece is sliced from; bidx addresses the slice in block-shape units.
    # Extra scalar-prefetch refs (the fused kernel's bnd flags) don't
    # steer piece addressing. Multi-field stores carry a leading channel
    # axis whose single block always sits at index 0.
    idx = (nbr_ref[i, col],) + bidx
    return (0,) + idx if channels else idx


def _piece_specs(T: int, h: int, channels: int | None = None) -> list:
    """The 27 neighbour-slice BlockSpecs for a halo of width h (h | T).

    Piece extent per axis is (h, T, h) — low halo, centre, high halo —
    and the low piece reads the neighbour's *last* h-slab while centre
    and high read from its first, addressed in block-shape units.
    ``channels=C`` prepends the whole-store channel axis of the
    multi-field ``(C, nb, T³)`` store (DESIGN.md §9) to every piece, so
    one grid step streams the window of all C fields.
    """
    sz = (h, T, h)
    last = (T // h - 1, 0, 0)
    specs = []
    for a in range(3):
        for b in range(3):
            for c in range(3):
                col = a * 9 + b * 3 + c
                shape = (1, sz[a], sz[b], sz[c])
                if channels is not None:
                    shape = (channels,) + shape
                specs.append(pl.BlockSpec(
                    shape,
                    functools.partial(_piece_index, col=col,
                                      bidx=(last[a], last[b], last[c]),
                                      channels=channels is not None)))
    return specs


@functools.partial(jax.jit, static_argnames=("g", "interpret"))
def stencil_sum_resident(store: jnp.ndarray, weights: jnp.ndarray,
                         nbr: jnp.ndarray, *, g: int,
                         interpret: bool = True) -> jnp.ndarray:
    """In-kernel halo streaming over the persistent block store.

    store:   (nb, T, T, T)  — SFC-ordered, *no* halo duplication
    weights: (2g+1, 2g+1, 2g+1)
    nbr:     (nb, 27) int32 — full periodic neighbour table of the same
             ordering (core.neighbors.neighbor_table), scalar-prefetched
    returns: (nb, T, T, T) float32, bit-identical to
             stencil_sum_blocks(blockize_with_halo(...), ...)

    Halo pieces are addressed in block-shape units, so g must divide T
    (g ∈ {1, 2, 4, ...} for T = 8; use the repack form otherwise).
    """
    nb, T = store.shape[0], store.shape[1]
    s = 2 * g + 1
    assert store.shape == (nb, T, T, T), store.shape
    assert weights.shape == (s, s, s), (weights.shape, s)
    assert nbr.shape == (nb, 27), nbr.shape
    if g > T or T % g:
        raise ValueError(f"resident kernel needs g | T, got T={T}, g={g}")

    in_specs = [pl.BlockSpec((s, s, s), lambda i, nbr_ref: (0, 0, 0))]
    in_specs += _piece_specs(T, g)
    kern = functools.partial(_resident_kernel, T=T, s=s)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((nb, T, T, T), jnp.float32),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, T, T, T), lambda i, nbr_ref: (i, 0, 0, 0)),
        ),
        interpret=interpret,
    )(nbr.astype(jnp.int32), weights, *([store] * 27))


# ------------------------------------------------------- temporal-blocked form

def _fused_kernel(nbr_ref, bnd_ref, w_ref, *refs, T: int, s: int, g: int,
                  S: int, rule, bc):
    """S substeps of tap-sum + update rule, entirely in VMEM.

    The assembled window starts at (C, (T+2·S·g)³) and shrinks by g per
    side each substep — boundary sites are recomputed redundantly instead
    of re-read from HBM (DESIGN.md §4). Nothing intermediate (tap sums,
    partial states) ever touches HBM; the single write is the C·T³ tile.
    Every substep tap-sums **all C channels** and hands the stacked
    fields to the rule (DESIGN.md §9) — C=1 rules see a leading axis of
    one, bit-identical to the scalar form.

    Clamped runs (DESIGN.md §8): before every substep, the outer
    ``g·(S-u)`` ghost layers on faces flagged in ``bnd_ref`` (the second
    scalar-prefetch operand) are substituted with boundary values —
    dirichlet constants or the replicated domain-edge plane, per channel
    — so domain sites only ever consume valid taps and clamped faces
    temporally block exactly as deep as periodic ones.
    """
    o_ref = refs[-1]
    x = _assemble_window(refs[:-1])  # (T+2·S·g,)³ f32, or (C, …) stacked
    multi = x.ndim == 4
    i = pl.program_id(0)
    flags = tuple(bnd_ref[i, c] for c in range(6))
    for u in range(S):
        x = apply_window_bc(x, flags, g * (S - u), bc)
        out_e = T + 2 * g * (S - 1 - u)      # window edge after this substep
        if multi:
            tap = jnp.stack([_tap_sum(x[c], w_ref, out_e, s)
                             for c in range(x.shape[0])])
            centre = x[:, g:g + out_e, g:g + out_e, g:g + out_e]
        else:
            tap = _tap_sum(x, w_ref, out_e, s)
            centre = x[g:g + out_e, g:g + out_e, g:g + out_e]
        x = rule.apply(centre, tap, g)
    if multi:
        o_ref[:, 0] = x.astype(o_ref.dtype)
    else:
        o_ref[0] = x.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("g", "S", "rule", "bc", "interpret"))
def stencil_step_fused(store: jnp.ndarray, weights: jnp.ndarray,
                       nbr: jnp.ndarray, bnd: jnp.ndarray | None = None,
                       *, g: int, S: int = 1, rule: str = "gol",
                       bc: BoundarySpec | MixedBoundary | str = PERIODIC,
                       interpret: bool = True) -> jnp.ndarray:
    """S fused timesteps over the resident store, one HBM round-trip.

    store:   (nb_src, T, T, T) — or the multi-field ``(C, nb_src, T³)``
             stacked store (DESIGN.md §9) when the rule declares C > 1 —
             SFC-ordered, no halo duplication, persists across launches
             (stencil/pipeline.ResidentPipeline). May hold *more* blocks
             than the grid computes: the distributed pipeline appends
             exchanged shell blocks after the core store
             (core/neighbors.extended_neighbor_table) and the kernel
             only writes the nbr-indexed core. All C channels share the
             one block permutation, neighbour table and grid: one grid
             step assembles C windows and writes C tiles.
    weights: (2g+1, 2g+1, 2g+1) tap weights (ops.uniform_weights for the
             classic neighbour-count rules), shared by every channel
    nbr:     (nb, 27) int32 neighbour table (core.neighbors — periodic,
             clamped, mixed, or extended), scalar-prefetched; nb ≤
             nb_src, and column SELF_COL must be the row index (the
             builders guarantee it)
    bnd:     (nb, 6) int32 clamped-domain-face flags per block, OFFSETS_FACE
             column order (core.neighbors.boundary_face_table; the
             distributed pipeline masks it by mesh position). Required
             when ``bc`` is clamped; ignored (may be None) for periodic.
    g:       stencil radius; S: substeps per launch; rule: kernels/rules.py
             registry key ("gol" | "jacobi" | "identity" | "wave") — the
             rule's declared ``channels`` must match the store's C
    bc:      boundary contract (core.boundary): "periodic" (default) |
             "dirichlet" | "neumann0" | a per-axis ``MixedBoundary``,
             applied to every channel alike
    returns: same shape as ``store``'s computed core, in store dtype —
             bit-identical (for f32 stores) to S sequential resident
             steps of the same rule and boundary.

    Halo pieces have extent S·g and are addressed in block-shape units,
    so S·g must divide T (deep temporal blocking needs S·g ≤ T: the
    window may only reach into directly adjacent blocks). Substeps run
    in f32; non-f32 stores would round once per launch instead of once
    per step, so bit-identity to the sequential path is f32-only.
    """
    r = get_rule(rule)
    multi = store.ndim == 5
    C = store.shape[0] if multi else 1
    if C != r.channels:
        raise ValueError(
            f"rule {r.name!r} advances {r.channels} channel(s) but the store "
            f"carries {C} (shape {store.shape}); stack the fields on the "
            "leading axis (core.layout.blockize_fields)")
    nb_src, T = store.shape[-4], store.shape[-3]
    s = 2 * g + 1
    bc = as_boundary(bc)
    assert store.shape[-4:] == (nb_src, T, T, T), store.shape
    assert weights.shape == (s, s, s), (weights.shape, s)
    nb = nbr.shape[0]
    assert nbr.shape == (nb, 27) and nb <= nb_src, (nbr.shape, store.shape)
    h = S * g
    if S < 1 or h > T or T % h:
        raise ValueError(
            f"fused kernel needs 1 <= S and S*g | T, got T={T}, g={g}, S={S}")
    if bc.clamped and bnd is None:
        raise ValueError(f"bc={bc.kind!r} needs the (nb, 6) bnd flag table "
                         "(core.neighbors.boundary_face_table)")
    if bnd is None:
        bnd = jnp.zeros((nb, 6), jnp.int32)
    assert bnd.shape == (nb, 6), bnd.shape

    in_specs = [pl.BlockSpec((s, s, s), lambda i, nbr_ref, bnd_ref: (0, 0, 0))]
    in_specs += _piece_specs(T, h, channels=C if multi else None)
    if multi:
        out_shape = jax.ShapeDtypeStruct((C, nb, T, T, T), store.dtype)
        out_spec = pl.BlockSpec((C, 1, T, T, T),
                                lambda i, nbr_ref, bnd_ref: (0, i, 0, 0, 0))
    else:
        out_shape = jax.ShapeDtypeStruct((nb, T, T, T), store.dtype)
        out_spec = pl.BlockSpec((1, T, T, T),
                                lambda i, nbr_ref, bnd_ref: (i, 0, 0, 0))
    kern = functools.partial(_fused_kernel, T=T, s=s, g=g, S=S,
                             rule=r, bc=bc)
    return pl.pallas_call(
        kern,
        out_shape=out_shape,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nb,),
            in_specs=in_specs,
            out_specs=out_spec,
        ),
        interpret=interpret,
    )(nbr.astype(jnp.int32), bnd.astype(jnp.int32), weights, *([store] * 27))
