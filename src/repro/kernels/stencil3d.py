"""Pallas TPU kernel: SFC-blocked 3D weighted stencil.

The paper's layout insight, TPU-native (DESIGN.md §2): the cube is stored
as ``(n_blocks, T+2g, T+2g, T+2g)`` halo-extended blocks whose order in
HBM follows a space-filling curve (core/layout.blockize_with_halo). The
kernel walks blocks *sequentially in memory* — so curve ordering makes the
HBM→VMEM stream of neighbouring blocks (which share halo data, already
duplicated) contiguous, the HBM/VMEM analogue of the paper's cache-line
argument. One grid step = one block: load ``(T+2g)³`` window into VMEM,
produce a ``T³`` tile.

VMEM budget: ``4B·((T+2g)³ + T³ + (2g+1)³)`` — e.g. T=32, g=1 → ~290 KiB,
far under the ~16 MiB/core budget, leaving room for Pallas' double
buffering of the streamed blocks.  MXU note: a pure stencil is VPU work
(elementwise FMA); the kernel unrolls the (2g+1)³ taps for g ≤ 2 so the
adds pipeline, and falls back to a ``fori_loop`` for larger g to bound
code size. Production layouts would pad the minor dim to the 128-lane
register width; correctness here is validated in interpret mode against
ref.stencil_sum_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stencil_sum_blocks"]

_UNROLL_TAP_LIMIT = 125  # unroll (2g+1)^3 taps up to g=2


def _kernel_unrolled(w_ref, x_ref, o_ref, *, T: int, s: int):
    x = x_ref[0].astype(jnp.float32)
    acc = jnp.zeros((T, T, T), dtype=jnp.float32)
    for dk in range(s):
        for di in range(s):
            for dj in range(s):
                acc = acc + w_ref[dk, di, dj].astype(jnp.float32) * (
                    x[dk:dk + T, di:di + T, dj:dj + T])
    o_ref[0] = acc


def _kernel_looped(w_ref, x_ref, o_ref, *, T: int, s: int):
    x = x_ref[0].astype(jnp.float32)

    def body(t, acc):
        dk = t // (s * s)
        di = (t // s) % s
        dj = t % s
        win = jax.lax.dynamic_slice(x, (dk, di, dj), (T, T, T))
        return acc + w_ref[dk, di, dj].astype(jnp.float32) * win

    acc = jax.lax.fori_loop(0, s * s * s, body,
                            jnp.zeros((T, T, T), dtype=jnp.float32))
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("g", "interpret"))
def stencil_sum_blocks(blocks: jnp.ndarray, weights: jnp.ndarray, *,
                       g: int, interpret: bool = True) -> jnp.ndarray:
    """acc[b] = sum_d w[d] * blocks[b, z+d] for every block b.

    blocks:  (nb, T+2g, T+2g, T+2g)  — SFC-ordered, halo-extended
    weights: (2g+1, 2g+1, 2g+1)
    returns: (nb, T, T, T) float32
    """
    nb, W = blocks.shape[0], blocks.shape[1]
    s = 2 * g + 1
    T = W - 2 * g
    assert weights.shape == (s, s, s), (weights.shape, s)
    body = _kernel_unrolled if s ** 3 <= _UNROLL_TAP_LIMIT else _kernel_looped
    kern = functools.partial(body, T=T, s=s)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((nb, T, T, T), jnp.float32),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((s, s, s), lambda i: (0, 0, 0)),        # weights: resident
            pl.BlockSpec((1, W, W, W), lambda i: (i, 0, 0, 0)),  # one block/step
        ],
        out_specs=pl.BlockSpec((1, T, T, T), lambda i: (i, 0, 0, 0)),
        interpret=interpret,
    )(weights, blocks)
