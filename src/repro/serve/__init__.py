"""Serving: LM decode scaffold + the hardened stencil ROI-query service.

Two front doors share this package (DESIGN.md §11):

- the LM path: jit'd decode step + batched greedy driver
  (serve_step.py, launch/serve.py's default mode);
- the stencil path: axis-aligned ROI queries over the curve-ordered
  block store — contiguous curve-range decomposition (roi.py) fronted
  by a deadline/retry/integrity-hardened service (service.py,
  ``launch/serve.py --stencil``).
"""

from .serve_step import make_serve_step, greedy_decode  # noqa: F401
from .roi import (  # noqa: F401
    ROI, StoreLayout, extract_roi, merge_blocks_to_ranges, ranges_to_blocks,
    roi_model, roi_to_ranges,
)
from .service import (  # noqa: F401
    FetchError, QUERY_STATUSES, QueryResult, StencilQueryService,
)
