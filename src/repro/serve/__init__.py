"""Serving: jit'd decode step + batched driver."""

from .serve_step import make_serve_step, greedy_decode  # noqa: F401
