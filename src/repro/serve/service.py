"""Hardened ROI-query service over the curve-ordered block store.

:class:`StencilQueryService` fronts a ``(C, nb, T³)`` block-store
snapshot with the robustness layer a serving path needs from day one
(DESIGN.md §11): a query that cannot be answered correctly and on time
degrades into a *typed* partial response — never a hang, never a
silently wrong payload.

The contract, fault by fault (launch/faults.ServeFaultPlan injects all
of these; tests/test_serve_roi.py asserts every row of the matrix):

- **slow fetch** — each fetch attempt is preceded by a deadline check;
  time lost to a slow storage tier surfaces as ``status="degraded"``
  with the undelivered blocks named in ``missing_ranges``.
- **failed fetch** — bounded retry with exponential backoff (sleeps
  never overshoot the deadline); transient faults recover to
  ``status="ok"``, exhausted budgets degrade.
- **bit-flipped block** — every fetched block is crc32-verified against
  the integrity manifest built from the authoritative store at
  construction; a mismatch counts as a failed attempt and is retried
  (the same crc/quarantine idiom as repro.checkpoint.ckpt).
- **cache poison** — cache entries carry their crc and are verified on
  every hit; a corrupt entry is quarantined (dropped + logged) and the
  block re-fetched, so poison can never reach a payload.
- **deadline exceeded / overload** — per-request deadlines bound every
  loop, and admission control sheds load beyond ``max_in_flight``
  concurrent queries with ``status="rejected"`` before any work starts.

Contiguity is what makes the cache/fetch economics work: the ROI
decomposes into curve ranges (serve/roi.py) and cache *misses* are
fetched one contiguous run at a time — on a curve with good 3-D
locality a whole query is a handful of sequential reads
(``fetch_calls`` in the result records exactly how many).

The service is thread-safe (query_batch drives it from a pool); the
clock and sleep are injectable so the deadline machinery is exactly
testable without real waiting.
"""

from __future__ import annotations

import threading
import time
import zlib
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np

from .roi import (ROI, StoreLayout, _as_store5, extract_roi,
                  merge_blocks_to_ranges, ranges_to_blocks, roi_to_ranges)

__all__ = ["StencilQueryService", "QueryResult", "FetchError",
           "QUERY_STATUSES"]

#: the typed outcome vocabulary — every query ends in exactly one of these
QUERY_STATUSES = ("ok", "degraded", "rejected", "error")


class FetchError(RuntimeError):
    """A storage fetch failed (transient or injected). Retried with
    backoff up to the service's budget; never propagates to callers —
    exhausted budgets surface as a degraded/error QueryResult."""


@dataclass(frozen=True)
class QueryResult:
    """Typed outcome of one ROI query — the degraded-response schema
    (DESIGN.md §11).

    status:         "ok" (full payload) | "degraded" (partial payload,
                    ``missing_ranges`` non-empty) | "rejected" (load
                    shed at admission, no work done) | "error" (nothing
                    deliverable)
    roi:            the query box
    payload:        dense ``(C,) + roi.shape`` array (C=1: plain 3-D);
                    missing blocks' footprints hold ``fill_value``
                    (NaN); None for rejected/error
    missing_ranges: contiguous curve ranges NOT delivered — the explicit
                    manifest a client needs to re-ask for exactly the
                    missing data
    ranges:         the full decomposition of the ROI
    retries:        fetch attempts beyond the first, summed over ranges
    integrity_failures: fetched blocks that failed manifest crc
                    (bit-flip faults) — each also counts one retry
    quarantined:    poisoned cache entries dropped by verify-on-hit
    cache_hits/cache_misses/fetch_calls: cache economics of this query
    elapsed_s:      service-clock duration
    error:          human-readable reason for degraded/rejected/error
    """
    status: str
    roi: ROI
    payload: "np.ndarray | None" = None
    missing_ranges: tuple = ()
    ranges: tuple = ()
    retries: int = 0
    integrity_failures: int = 0
    quarantined: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    fetch_calls: int = 0
    elapsed_s: float = 0.0
    error: "str | None" = None

    def __post_init__(self):
        if self.status not in QUERY_STATUSES:
            raise ValueError(f"unknown status {self.status!r} "
                             f"(expected one of {QUERY_STATUSES})")

    @property
    def complete(self) -> bool:
        return self.status == "ok"


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


@dataclass
class StencilQueryService:
    """ROI queries over one block-store snapshot, hardened end to end.

    store:        the ``(nb, T³)`` / ``(C, nb, T³)`` snapshot (numpy or
                  device array; copied to host once)
    layout:       :class:`StoreLayout` (or use :meth:`from_pipeline`)
    fetch:        ``fetch(start, stop) -> (C, n, T, T, T)`` storage read
                  of one contiguous curve range; default reads the
                  snapshot. Fault injection wraps this
                  (launch/faults.ServeFaultPlan).
    cache_blocks: LRU capacity in blocks (0 disables caching)
    deadline_s:   default per-request wall budget
    max_retries:  fetch attempts per contiguous run beyond the first
    backoff_s:    base of the exponential retry backoff
    max_in_flight: admission budget — queries beyond this many
                  concurrent are shed with status="rejected"
    clock/sleep:  injectable time sources (tests pin them)
    """
    store: np.ndarray
    layout: StoreLayout
    fetch: "callable | None" = None
    cache_blocks: int = 256
    deadline_s: float = 1.0
    max_retries: int = 2
    backoff_s: float = 0.01
    max_in_flight: int = 8
    clock: "callable" = time.monotonic
    sleep: "callable" = time.sleep

    # internal state ------------------------------------------------------
    _cache: "OrderedDict[int, tuple[np.ndarray, int]]" = field(
        default_factory=OrderedDict, repr=False)
    _lock: threading.RLock = field(default_factory=threading.RLock,
                                   repr=False)
    _in_flight: int = field(default=0, repr=False)
    _stats: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        self.store = np.asarray(self.store)
        store5 = _as_store5(self.store, self.layout)
        if self.fetch is None:
            self.fetch = lambda a, b: store5[:, a:b]
        # integrity manifest: authoritative per-block crc32, computed once
        # from the snapshot — every fetched block and every cache hit is
        # verified against it (the ckpt.py idiom, DESIGN.md §10/§11)
        self._manifest = np.array(
            [_crc(store5[:, b]) for b in range(self.layout.nb)],
            dtype=np.int64)
        self._stats = {"queries": 0, "shed": 0, "cache_hits": 0,
                       "cache_misses": 0, "fetch_calls": 0,
                       "quarantined": 0, "integrity_failures": 0,
                       "retries": 0, "degraded": 0, "errors": 0}

    @classmethod
    def from_pipeline(cls, pipeline, store, **kw) -> "StencilQueryService":
        """Front a pipeline's block store (e.g. the state a
        ResidentPipeline run left behind)."""
        return cls(store=np.asarray(store),
                   layout=StoreLayout.from_pipeline(pipeline), **kw)

    # -- cache (LRU, crc-carrying, verify-on-hit) -------------------------
    def _cache_get(self, b: int) -> "np.ndarray | None":
        """A verified cache hit, or None. A corrupt entry (crc mismatch
        — cache poison) is quarantined: dropped, counted, re-fetched by
        the caller. Never returns poisoned bytes."""
        with self._lock:
            hit = self._cache.get(b)
            if hit is None:
                return None
            data, crc = hit
            if _crc(data) != crc:
                del self._cache[b]
                self._stats["quarantined"] += 1
                return "quarantined"
            self._cache.move_to_end(b)
            return data

    def _cache_put(self, b: int, data: np.ndarray) -> None:
        if self.cache_blocks <= 0:
            return
        data = np.ascontiguousarray(data)
        data.setflags(write=False)
        with self._lock:
            self._cache[b] = (data, _crc(data))
            self._cache.move_to_end(b)
            while len(self._cache) > self.cache_blocks:
                self._cache.popitem(last=False)

    def poison_cache(self, b: int) -> bool:
        """Fault injection: flip one bit of a cached block in place
        (True when the block was cached). Verify-on-hit must quarantine
        it — tests assert the payload stays bit-identical regardless."""
        with self._lock:
            hit = self._cache.get(b)
            if hit is None:
                return False
            data = np.array(hit[0])  # writable copy, keep recorded crc
            raw = data.reshape(-1).view(np.uint8)
            raw[raw.size // 2] ^= 0x04
            self._cache[b] = (data, hit[1])
            return True

    # -- fetch with deadline/retry/integrity ------------------------------
    def _fetch_run(self, start: int, stop: int, t_end: float, res: dict
                   ) -> "np.ndarray | None":
        """One contiguous run read under the deadline: bounded retry with
        exponential backoff; every block crc-verified against the
        manifest. None when the budget (time or retries) is exhausted."""
        attempt = 0
        while True:
            if self.clock() >= t_end:
                res["error"] = "deadline exceeded"
                return None
            try:
                res["fetch_calls"] += 1
                data = np.asarray(self.fetch(start, stop))
                if data.shape != (self.layout.channels, stop - start) + \
                        (self.layout.T,) * 3:
                    raise FetchError(f"short read: got {data.shape} for "
                                     f"range [{start}, {stop})")
                bad = [b for b in range(start, stop)
                       if _crc(data[:, b - start]) != self._manifest[b]]
                if bad:
                    res["integrity_failures"] += len(bad)
                    raise FetchError(
                        f"integrity failure: crc mismatch on block(s) "
                        f"{bad} of range [{start}, {stop})")
                return data
            except FetchError as e:
                res["error"] = str(e)
                if attempt >= self.max_retries:
                    return None
                attempt += 1
                res["retries"] += 1
                delay = self.backoff_s * (2 ** (attempt - 1))
                remaining = t_end - self.clock()
                if remaining <= 0:
                    res["error"] = "deadline exceeded"
                    return None
                self.sleep(min(delay, remaining))

    # -- the query --------------------------------------------------------
    def query(self, roi: ROI, *, deadline_s: "float | None" = None
              ) -> QueryResult:
        """Answer one ROI query with a typed outcome — see the module
        docstring for the full fault contract."""
        t0 = self.clock()
        with self._lock:
            self._stats["queries"] += 1
            if self._in_flight >= self.max_in_flight:
                self._stats["shed"] += 1
                return QueryResult(
                    status="rejected", roi=roi,
                    error=f"admission control: {self._in_flight} queries "
                          f"in flight >= budget {self.max_in_flight}",
                    elapsed_s=self.clock() - t0)
            self._in_flight += 1
        try:
            return self._query_admitted(roi, deadline_s, t0)
        finally:
            with self._lock:
                self._in_flight -= 1

    def _query_admitted(self, roi: ROI, deadline_s, t0) -> QueryResult:
        t_end = t0 + (self.deadline_s if deadline_s is None else deadline_s)
        ranges = roi_to_ranges(self.layout, roi)
        res = {"fetch_calls": 0, "retries": 0, "integrity_failures": 0,
               "cache_hits": 0, "cache_misses": 0, "error": None}
        got: dict[int, np.ndarray] = {}
        missing: list[int] = []
        quarantined = 0
        for start, stop in ranges:
            # cache pass: verified hits; poisoned entries quarantine here
            miss: list[int] = []
            for b in range(start, stop):
                if self.clock() >= t_end:
                    res["error"] = "deadline exceeded"
                    miss = None
                    break
                hit = self._cache_get(b)
                if isinstance(hit, np.ndarray):
                    res["cache_hits"] += 1
                    got[b] = hit
                    continue
                if hit == "quarantined":
                    quarantined += 1
                res["cache_misses"] += 1
                miss.append(b)
            if miss is None:  # deadline tripped mid-scan
                missing.extend(b for b in range(start, stop) if b not in got)
                continue
            # fetch pass: contiguous runs of misses, one storage read each
            for m0, m1 in merge_blocks_to_ranges(np.asarray(miss)):
                data = self._fetch_run(m0, m1, t_end, res)
                if data is None:
                    missing.extend(range(m0, m1))
                    continue
                for b in range(m0, m1):
                    blk = data[:, b - m0]
                    got[b] = blk
                    self._cache_put(b, blk)
        elapsed = self.clock() - t0
        with self._lock:
            for k in ("cache_hits", "cache_misses", "fetch_calls",
                      "retries", "integrity_failures"):
                self._stats[k] += res[k]
        missing_ranges = tuple(merge_blocks_to_ranges(np.asarray(missing)))
        if missing and not got:
            with self._lock:
                self._stats["errors"] += 1
            return QueryResult(
                status="error", roi=roi, payload=None,
                missing_ranges=missing_ranges, ranges=tuple(ranges),
                retries=res["retries"],
                integrity_failures=res["integrity_failures"],
                quarantined=quarantined, cache_hits=res["cache_hits"],
                cache_misses=res["cache_misses"],
                fetch_calls=res["fetch_calls"], elapsed_s=elapsed,
                error=res["error"] or "no blocks deliverable")
        payload = self._assemble(roi, ranges, got)
        status = "ok" if not missing else "degraded"
        if missing:
            with self._lock:
                self._stats["degraded"] += 1
        return QueryResult(
            status=status, roi=roi, payload=payload,
            missing_ranges=missing_ranges, ranges=tuple(ranges),
            retries=res["retries"],
            integrity_failures=res["integrity_failures"],
            quarantined=quarantined, cache_hits=res["cache_hits"],
            cache_misses=res["cache_misses"],
            fetch_calls=res["fetch_calls"], elapsed_s=elapsed,
            error=res["error"] if missing else None)

    def _assemble(self, roi: ROI, ranges, got: dict) -> np.ndarray:
        """Blocks → dense ROI box via the shared extract_roi decoder,
        with undelivered blocks left at NaN (the degraded fill)."""
        lay = self.layout
        sub = np.zeros((lay.channels, lay.nb) + (lay.T,) * 3,
                       dtype=self.store.dtype)
        for b, blk in got.items():
            sub[:, b] = blk
        skip = [b for b in ranges_to_blocks(ranges) if int(b) not in got]
        # C=1 payloads are plain 3-D boxes (the store convention)
        return extract_roi(sub if lay.channels > 1 else sub[0], lay, roi,
                           ranges=ranges, skip_blocks=skip)

    def query_batch(self, rois, *, deadline_s: "float | None" = None,
                    max_workers: "int | None" = None) -> list:
        """Concurrent batch of queries (order-preserving). Each query is
        independently admitted/deadlined; overload surfaces as typed
        ``rejected`` results, never an exception."""
        workers = max_workers or min(len(rois), self.max_in_flight + 2) or 1
        with ThreadPoolExecutor(max_workers=workers) as ex:
            return list(ex.map(
                lambda r: self.query(r, deadline_s=deadline_s), rois))

    def stats(self) -> dict:
        with self._lock:
            return dict(self._stats, cached_blocks=len(self._cache),
                        in_flight=self._in_flight)
