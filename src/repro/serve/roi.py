"""ROI → contiguous curve-range decomposition over the block store.

The paper's locality claim becomes a *serving-path* win here (DESIGN.md
§11): an axis-aligned region of interest (ROI) over the curve-ordered
``(C, nb, T³)`` block store decomposes into a handful of **contiguous**
curve-index ranges, so a bounding-box query is a few sequential reads
instead of nb scattered ones. Curves that preserve 3-D locality need
fewer ranges — an aligned power-of-two block cube is exactly *one*
hilbert/morton range (a complete octree subtree is a contiguous index
interval for any bit-hierarchical curve) where row-major needs one range
per (bk, bi) line. benchmarks/roi.py records the counts; the exemplar
repo this mirrors measured ~85% chunk utilisation under Hilbert vs ~40%
row-major for exactly this access pattern.

Pieces:

- :class:`ROI` — a half-open axis-aligned element box ``[lo, hi)``.
- :class:`StoreLayout` — the (M, T, kind, C) identity of a block store
  (``StoreLayout.from_pipeline`` lifts it off a ResidentPipeline).
- :func:`roi_to_ranges` — minimal sorted disjoint ``(start, stop)``
  curve-index ranges covering every block the ROI intersects.
- :func:`extract_roi` — decode *only* those blocks into a dense
  ``(C,) + roi.shape`` array, bit-identical to slicing the unblockized
  cube (asserted across orderings × boundaries × C in tests).
- :func:`roi_model` — blocks-touched / bytes-read / range-count
  accounting, the single source of truth behind the ``roi/`` benchmark
  rows (pinned exactly in CI).

Everything here is host-side numpy: the serving path reads a snapshot
of the store, it never traces.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.layout import block_order
from repro.core.orderings import block_index_3d

__all__ = ["ROI", "StoreLayout", "roi_to_ranges", "ranges_to_blocks",
           "merge_blocks_to_ranges", "extract_roi", "roi_model"]


@dataclass(frozen=True)
class ROI:
    """Half-open axis-aligned element box ``[lo, hi)`` in cube coords."""
    lo: tuple[int, int, int]
    hi: tuple[int, int, int]

    def __post_init__(self):
        lo = tuple(int(v) for v in self.lo)
        hi = tuple(int(v) for v in self.hi)
        object.__setattr__(self, "lo", lo)
        object.__setattr__(self, "hi", hi)
        if len(lo) != 3 or len(hi) != 3:
            raise ValueError(f"ROI needs 3-D lo/hi, got {lo}, {hi}")
        if any(l < 0 or l >= h for l, h in zip(lo, hi)):
            raise ValueError(f"empty or negative ROI [{lo}, {hi})")

    @property
    def shape(self) -> tuple[int, int, int]:
        return tuple(h - l for l, h in zip(self.lo, self.hi))

    def items(self) -> int:
        s = self.shape
        return s[0] * s[1] * s[2]

    def clipped(self, M: int) -> "ROI":
        if any(h > M for h in self.hi):
            raise ValueError(f"ROI {self.lo}..{self.hi} exceeds cube edge {M}")
        return self


@dataclass(frozen=True)
class StoreLayout:
    """Identity of a curve-ordered block store: cube edge M, block edge
    T (T | M), block-grid curve ``kind``, channel count C (DESIGN.md §9).
    """
    M: int
    T: int
    kind: str = "morton"
    channels: int = 1

    def __post_init__(self):
        if self.M % self.T or self.M < self.T:
            raise ValueError(f"block edge T={self.T} does not tile "
                             f"cube edge M={self.M}")
        if self.channels < 1:
            raise ValueError(f"channels must be >= 1, got {self.channels}")

    @classmethod
    def from_pipeline(cls, pipeline) -> "StoreLayout":
        """Lift the layout off a ResidentPipeline (or anything with
        M/T/kind/channels)."""
        return cls(M=pipeline.M, T=pipeline.T, kind=pipeline.kind,
                   channels=pipeline.channels)

    @property
    def nt(self) -> int:
        return self.M // self.T

    @property
    def nb(self) -> int:
        return self.nt ** 3

    def block_bytes(self, itemsize: int = 4) -> int:
        """Payload bytes of one block across all channels — the unit of
        both the cache and the bytes-read model."""
        return self.channels * self.T ** 3 * itemsize

    def block_box(self, roi: ROI) -> tuple[tuple, tuple]:
        """Half-open block-coordinate box the ROI intersects."""
        roi.clipped(self.M)
        lo = tuple(l // self.T for l in roi.lo)
        hi = tuple((h + self.T - 1) // self.T for h in roi.hi)
        return lo, hi


def merge_blocks_to_ranges(indices: np.ndarray) -> list[tuple[int, int]]:
    """Sorted unique curve indices → minimal disjoint ``(start, stop)``
    half-open ranges (consecutive indices merge)."""
    idx = np.unique(np.asarray(indices, dtype=np.int64))
    if idx.size == 0:
        return []
    breaks = np.nonzero(np.diff(idx) != 1)[0]
    starts = np.concatenate(([0], breaks + 1))
    stops = np.concatenate((breaks, [idx.size - 1]))
    return [(int(idx[a]), int(idx[b]) + 1) for a, b in zip(starts, stops)]


def roi_to_ranges(layout: StoreLayout, roi: ROI) -> list[tuple[int, int]]:
    """Minimal sorted disjoint contiguous curve-index ranges covering
    every block the ROI intersects.

    Exactness contract (property-tested): the union of the returned
    ranges equals the set of curve indices of blocks whose T³ extent
    intersects ``roi`` — nothing missing, nothing extra — and no two
    returned ranges are adjacent (the decomposition is minimal).
    """
    (bk0, bi0, bj0), (bk1, bi1, bj1) = layout.block_box(roi)
    kk, ii, jj = np.meshgrid(np.arange(bk0, bk1), np.arange(bi0, bi1),
                             np.arange(bj0, bj1), indexing="ij")
    idx = block_index_3d(layout.kind, kk.ravel(), ii.ravel(), jj.ravel(),
                         layout.nt)
    return merge_blocks_to_ranges(idx)


def ranges_to_blocks(ranges) -> np.ndarray:
    """Flatten ``(start, stop)`` ranges back to sorted curve indices."""
    if not ranges:
        return np.empty(0, dtype=np.int64)
    return np.concatenate([np.arange(a, b, dtype=np.int64)
                           for a, b in ranges])


def _as_store5(store: np.ndarray, layout: StoreLayout) -> np.ndarray:
    """View any store as ``(C, nb, T, T, T)`` (C=1 stores are 4-D)."""
    store = np.asarray(store)
    if store.ndim == 4:
        store = store[None]
    C, nb, T = store.shape[0], store.shape[1], store.shape[2]
    if (C, nb, T) != (layout.channels, layout.nb, layout.T) or \
            store.shape[2:] != (T, T, T):
        raise ValueError(f"store shape {store.shape} does not match "
                         f"layout {layout}")
    return store


def extract_roi(store: np.ndarray, layout: StoreLayout, roi: ROI,
                ranges=None, *, fill_value: float = np.nan,
                skip_blocks=()) -> np.ndarray:
    """Decode only the ROI's blocks into a dense ``(C,) + roi.shape``
    array (C=1 inputs return the plain 3-D box).

    ``ranges`` (default: :func:`roi_to_ranges`) restricts which curve
    ranges are materialised; blocks listed in ``skip_blocks`` (or blocks
    absent from ``ranges``) leave their footprint at ``fill_value`` —
    this is the degraded-response path of serve/service.py, where the
    ``missing_ranges`` manifest names exactly the unfilled blocks.
    """
    squeeze = np.asarray(store).ndim == 4
    store = _as_store5(store, layout)
    if ranges is None:
        ranges = roi_to_ranges(layout, roi)
    skip = set(int(b) for b in skip_blocks)
    T = layout.T
    bo = block_order(layout.kind, layout.nt)
    out = np.full((layout.channels,) + roi.shape, fill_value,
                  dtype=store.dtype)
    for b in ranges_to_blocks(ranges):
        if int(b) in skip:
            continue
        ok, oi, oj = (int(c) * T for c in bo[b])  # block origin, elements
        sl_out, sl_blk = [], []
        for ax, o in enumerate((ok, oi, oj)):
            lo = max(roi.lo[ax], o)
            hi = min(roi.hi[ax], o + T)
            if lo >= hi:
                sl_out = None
                break
            sl_out.append(slice(lo - roi.lo[ax], hi - roi.lo[ax]))
            sl_blk.append(slice(lo - o, hi - o))
        if sl_out is None:  # range includes blocks outside the ROI box
            continue
        out[(slice(None), *sl_out)] = store[(slice(None), int(b), *sl_blk)]
    return out[0] if squeeze else out


def roi_model(layout: StoreLayout, roi: ROI, itemsize: int = 4) -> dict:
    """Deterministic accounting of one ROI query — the model the
    ``roi/`` benchmark rows stamp and CI pins exactly.

    blocks_touched: blocks whose extent intersects the ROI (= the block
                    box volume — curve-independent)
    ranges:         contiguous curve ranges (curve-DEpendent: the
                    locality signal; hilbert needs strictly fewer than
                    row-major on aligned power-of-two ROIs)
    bytes_read:     blocks_touched · C · T³ · itemsize — a range read
                    always moves whole blocks
    payload_bytes:  C · |roi| · itemsize — the useful bytes
    utilization:    payload / read (the exemplar repo's ~85% vs ~40%)
    """
    (bk0, bi0, bj0), (bk1, bi1, bj1) = layout.block_box(roi)
    blocks = (bk1 - bk0) * (bi1 - bi0) * (bj1 - bj0)
    ranges = roi_to_ranges(layout, roi)
    bytes_read = blocks * layout.block_bytes(itemsize)
    payload = layout.channels * roi.items() * itemsize
    return {
        "blocks_touched": blocks,
        "ranges": len(ranges),
        "bytes_read": bytes_read,
        "payload_bytes": payload,
        "utilization": payload / bytes_read,
    }
