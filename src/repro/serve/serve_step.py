"""Serving: jit'd single-token decode step + a batched decode driver.

``make_serve_step`` is what the dry-run lowers for the decode_32k /
long_500k shapes: one new token against a seq_len-deep cache. The driver
implements greedy/temperature sampling over a batch of concurrent
requests (static batch; a production server would add continuous
batching on top — the step function is already shape-stable in that
regime because the cache is preallocated at max_len).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.zoo import Model

__all__ = ["make_serve_step", "greedy_decode"]


def make_serve_step(model: Model, *, sample: bool = False,
                    temperature: float = 1.0):
    def serve_step(params, cache, batch):
        """batch: {tokens:(B,1) int32, cur:() int32, rng: key (if sampling)}."""
        logits, cache = model.decode(params, cache, batch)
        lg = logits[:, -1]
        if sample:
            nxt = jax.random.categorical(batch["rng"], lg / temperature, -1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32), cache

    return serve_step


def greedy_decode(model: Model, params, prompts: jnp.ndarray, n_new: int,
                  max_len: int):
    """Prefill via teacher-forced steps, then greedy decode n_new tokens.

    prompts: (B, P) int32. Returns (B, n_new) int32.
    """
    B, P = prompts.shape
    cache = model.init_cache(B, max_len, jnp.float32)
    step = jax.jit(make_serve_step(model))
    tok = prompts[:, :1]
    out = []
    for t in range(P + n_new - 1):
        batch = {"tokens": tok, "cur": jnp.asarray(t, jnp.int32)}
        nxt, cache = step(params, cache, batch)
        if t + 1 < P:
            tok = prompts[:, t + 1:t + 2]
        else:
            tok = nxt[:, None]
            out.append(nxt)
    return jnp.stack(out, axis=1)
