"""Fault-tolerant checkpointed stencil runs (DESIGN.md §10).

At the scale the paper targets, faults are the norm: a run of thousands
of substeps must survive killed processes, torn or bit-flipped
checkpoint files, and silently corrupted state. :class:`CheckpointedRun`
wraps both stencil pipelines (resident and distributed) in a driver that

- chunks ``n_steps`` into checkpoint intervals and atomically snapshots
  the **canonical** (curve-independent) state through
  ``repro.checkpoint.ckpt`` — per-leaf crc32s verified on restore — with
  a manifest recording ``{step, rule, C, bc, shape, crc, bounds, …}``;
- on ``resume=True`` restores the newest *valid* checkpoint (corrupt or
  partial dirs fall back to the previous one) and re-blockizes onto
  **this** pipeline — which may use a different ordering, block edge T,
  fused depth S, kernel family, or mesh shape than the run that wrote
  the checkpoint. Because every pipeline form is bit-identical (f32) to
  every other on the same rule, a resumed run is bit-identical to the
  uninterrupted one even across such an elastic reshard;
- guards the state at every checkpoint boundary: a NaN/Inf scan plus
  per-rule invariants (gol states are exactly {0,1}; jacobi — a
  box-filter mean — obeys the discrete maximum principle and stays
  inside the initial range). A violation raises a structured
  :class:`RunHealthError` carrying the last good (checkpointed) step
  instead of checkpointing poison.

The resume contract: *physics* must match (rule, channel count C,
boundary contract, global state shape — validated against the
manifest); *layout and machine* may change (ordering/kind, T, S,
use_kernel, mesh shape). That split is exactly the paper's premise that
curve ordering is metadata, not state.

Fault injection plugs in through :class:`RunHooks`
(launch/faults.py builds these): extra chunk boundaries plus a callback
that may kill the process, raise, or poison the state mid-run.
"""

from __future__ import annotations

import os
import shutil
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core.boundary import BoundarySpec, MixedBoundary, as_boundary

from .halo import shard_state, unshard_state
from .pipeline import DistributedPipeline, ResidentPipeline

__all__ = ["CheckpointedRun", "RunHealthError", "RunHooks",
           "boundary_to_json", "health_check"]


class RunHealthError(RuntimeError):
    """A runtime guard tripped: the state violates its rule's invariants.

    step:           the step at which the violation was detected
    last_good_step: the newest durable checkpoint (resume from here)
    reason:         human-readable description of the violation
    """

    def __init__(self, reason: str, step: int, last_good_step: int):
        super().__init__(
            f"run health check failed at step {step}: {reason} "
            f"(last good checkpoint: step {last_good_step})")
        self.reason = reason
        self.step = step
        self.last_good_step = last_good_step


@dataclass(frozen=True)
class RunHooks:
    """Fault-injection surface of :class:`CheckpointedRun`.

    break_at:    extra steps the runner must treat as chunk boundaries
                 (so a fault can fire at *any* step k, not only at
                 checkpoint intervals)
    on_boundary: called at every break_at boundary with
                 ``(step, canonical_state)``; may raise (simulated
                 crash), call ``os._exit`` (real process death), or
                 return a replacement state (fault injection into the
                 store — the runner re-blockizes it). ``None`` leaves
                 the state untouched.
    """
    break_at: tuple = ()
    on_boundary: "Callable[[int, np.ndarray], Any] | None" = None


def boundary_to_json(bc: "BoundarySpec | MixedBoundary | str"):
    """JSON-able form of a boundary contract, for the run manifest."""
    bc = as_boundary(bc)
    if isinstance(bc, MixedBoundary):
        return {"kind": "mixed",
                "axes": [boundary_to_json(ax) for ax in bc.axes]}
    return {"kind": bc.kind, "value": bc.value}


# -- runtime guards ---------------------------------------------------------

def _guard_gol(a: np.ndarray, bounds) -> str | None:
    bad = ~((a == 0.0) | (a == 1.0))
    if bad.any():
        return (f"gol state must be exactly {{0, 1}}: "
                f"{int(bad.sum())} violating site(s), "
                f"first value {a[np.unravel_index(np.argmax(bad), a.shape)]!r}")
    return None


def _guard_jacobi(a: np.ndarray, bounds) -> str | None:
    if bounds is None:
        return None
    lo, hi = bounds
    eps = 1e-5 * (abs(lo) + abs(hi) + 1.0)  # f32 tap-sum rounding headroom
    if a.min() < lo - eps or a.max() > hi + eps:
        return (f"jacobi state escaped its maximum-principle range "
                f"[{lo}, {hi}]: observed [{a.min()}, {a.max()}]")
    return None


#: rule name -> extra invariant beyond the NaN/Inf scan (None = finite only)
RULE_GUARDS: dict[str, Callable[[np.ndarray, Any], "str | None"]] = {
    "gol": _guard_gol,
    "jacobi": _guard_jacobi,
}


def health_check(rule: str, state: np.ndarray,
                 bounds=None) -> "str | None":
    """Violation description, or None when the state is healthy.

    Every rule gets the NaN/Inf scan; rules in :data:`RULE_GUARDS` add
    their own invariant (``bounds`` is the rule-specific payload the
    manifest carries, e.g. jacobi's initial [min, max]).
    """
    a = np.asarray(state)
    if not np.isfinite(a).all():
        n = int((~np.isfinite(a)).sum())
        return f"non-finite state: {n} NaN/Inf site(s)"
    extra = RULE_GUARDS.get(rule)
    return extra(a, bounds) if extra else None


# -- the driver -------------------------------------------------------------

@dataclass
class CheckpointedRun:
    """Resumable, guarded K-step driver over a stencil pipeline.

    pipeline:  a :class:`ResidentPipeline` or :class:`DistributedPipeline`
               — the *target* configuration; a resumed run may differ
               from the writer in ordering/T/S/kernel family/mesh shape
               (the elastic reshard contract, DESIGN.md §10)
    ckpt_dir:  checkpoint directory (repro.checkpoint.ckpt layout)
    interval:  steps between checkpoints (the final step always
               checkpoints; ``interval`` need not divide ``n_steps`` —
               chunked and unchunked runs are bit-identical because
               S-deep and sequential launches are)
    guards:    run :func:`health_check` at every checkpoint boundary
               (violations raise :class:`RunHealthError` *before* the
               poisoned state can be checkpointed)
    hooks:     fault-injection surface (:class:`RunHooks`)
    keep:      retain only the newest ``keep`` checkpoints (None = all)
    retries:   save-I/O retry budget (ckpt.save retry-with-backoff)
    extra_meta: caller payload stored in every manifest (e.g. the init
               RNG seed), round-tripped under ``meta["extra"]``
    """
    pipeline: "ResidentPipeline | DistributedPipeline"
    ckpt_dir: str
    interval: int = 16
    guards: bool = True
    hooks: "RunHooks | None" = None
    keep: "int | None" = None
    retries: int = 2
    extra_meta: "dict | None" = None
    _runners: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")

    # -- pipeline adaptation ----------------------------------------------
    @property
    def distributed(self) -> bool:
        return isinstance(self.pipeline, DistributedPipeline)

    def expected_shape(self) -> tuple:
        p = self.pipeline
        box = p.global_shape if self.distributed else (p.M,) * 3
        return box if p.channels == 1 else (p.channels,) + tuple(box)

    def _to_internal(self, canonical: np.ndarray):
        p = self.pipeline
        if self.distributed:
            return shard_state(jnp.asarray(canonical), p.spec, p.procs)
        return p.to_blocks(jnp.asarray(canonical))

    def _to_canonical(self, internal) -> np.ndarray:
        p = self.pipeline
        if self.distributed:
            return np.asarray(unshard_state(internal, p.spec, p.global_shape))
        return np.asarray(p.to_cube(internal))

    def _advance(self, internal, k: int):
        if k not in self._runners:
            self._runners[k] = self.pipeline.run_fn(k)
        return self._runners[k](internal)

    # -- manifest ----------------------------------------------------------
    def _meta(self, step: int, canonical: np.ndarray, bounds) -> dict:
        p = self.pipeline
        return {
            "step": step,
            "rule": p.rule,
            "fields": p.channels,
            "bc": boundary_to_json(p.bc),
            "shape": list(canonical.shape),
            "dtype": str(canonical.dtype),
            "state_crc32": zlib.crc32(
                np.ascontiguousarray(canonical).tobytes()),
            "bounds": bounds,
            "interval": self.interval,
            "extra": self.extra_meta or {},
        }

    def _validate_meta(self, meta: dict, exp_shape: tuple) -> None:
        """The resume contract: physics must match, layout may change."""
        p = self.pipeline
        checks = [
            ("rule", meta.get("rule"), p.rule),
            ("fields", meta.get("fields"), p.channels),
            ("bc", meta.get("bc"), boundary_to_json(p.bc)),
            ("shape", tuple(meta.get("shape", ())), tuple(exp_shape)),
        ]
        bad = [f"{k}: checkpoint has {a!r}, pipeline wants {b!r}"
               for k, a, b in checks if a != b]
        if bad:
            raise ValueError(
                "checkpoint is for different physics — resume may change "
                "ordering/T/S/mesh but not rule/C/bc/shape: "
                + "; ".join(bad))

    # -- the run -----------------------------------------------------------
    def run(self, state, n_steps: int, *, resume: bool = True) -> np.ndarray:
        """Advance ``state`` (canonical, curve-independent form) by
        ``n_steps``, checkpointing every ``interval`` steps. With
        ``resume=True`` an existing valid checkpoint overrides ``state``
        and the run continues from its step — bit-identical (f32) to the
        uninterrupted run regardless of which pipeline wrote it."""
        state = np.asarray(state)
        exp_shape = self.expected_shape()
        if state.shape != tuple(exp_shape):
            raise ValueError(f"state shape {state.shape} does not match "
                             f"pipeline ({tuple(exp_shape)})")
        start, bounds, restored = 0, None, False
        if resume:
            try:
                tree, meta = ckpt.restore(self.ckpt_dir)
            except FileNotFoundError:
                pass
            else:
                self._validate_meta(meta, exp_shape)
                state = np.asarray(tree["state"])
                start, bounds = int(meta["step"]), meta.get("bounds")
                restored = True
        if start > n_steps:
            raise ValueError(f"checkpoint at step {start} is beyond the "
                             f"requested n_steps={n_steps}")
        if bounds is None:
            bounds = [float(state.min()), float(state.max())]
        if not restored:
            self._checkpoint(start, state, bounds, last_good=start)
        if start == n_steps:
            return state

        breaks = set(self.hooks.break_at) if self.hooks else set()
        bounds_steps = sorted(
            {s for s in range(start + 1, n_steps + 1)
             if s % self.interval == 0 or s == n_steps} |
            {s for s in breaks if start < s <= n_steps})
        internal = self._to_internal(state)
        step, last_good = start, start
        canonical = state
        for stop in bounds_steps:
            internal = self._advance(internal, stop - step)
            step = stop
            fresh = None
            if step in breaks:
                fresh = self._to_canonical(internal)
                repl = self.hooks.on_boundary(step, fresh) \
                    if self.hooks.on_boundary else None
                if repl is not None:  # injected state (e.g. NaN poison)
                    fresh = np.asarray(repl)
                    internal = self._to_internal(fresh)
            if step % self.interval == 0 or step == n_steps:
                canonical = self._to_canonical(internal) \
                    if fresh is None else fresh
                self._checkpoint(step, canonical, bounds, last_good)
                last_good = step
            elif fresh is not None:
                canonical = fresh
        return canonical

    def _checkpoint(self, step: int, canonical: np.ndarray, bounds,
                    last_good: int) -> None:
        if self.guards:
            reason = health_check(self.pipeline.rule, canonical, bounds)
            if reason is not None:
                raise RunHealthError(reason, step, last_good)
        ckpt.save(self.ckpt_dir, step, {"state": canonical},
                  meta=self._meta(step, canonical, bounds),
                  retries=self.retries)
        if self.keep is not None:
            for old in ckpt.valid_steps(self.ckpt_dir)[:-self.keep]:
                shutil.rmtree(
                    os.path.join(self.ckpt_dir, f"step_{old:08d}"),
                    ignore_errors=True)
