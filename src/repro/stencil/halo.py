"""Distributed halo exchange with SFC pack/unpack (paper §3.2/§4, on mesh).

The paper's halo pattern — pack six width-g faces into contiguous buffers,
exchange with neighbours, unpack — mapped to JAX: ``shard_map`` over a 3D
device mesh, ``jax.lax.ppermute`` ring shifts per axis. The slab-axis
(k) faces are packed straight from the shard's *path-ordered* storage via
the precomputed index lists (kernels.ops.pack_surface) — the paper's
mechanism; the remaining axes pack slices of the progressively extended
cube (the standard corner-correct axis-sequential scheme).

On a TPU torus with Hilbert device ordering (launch/mesh.py) the six
ppermutes are single-hop ICI transfers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import OrderingSpec, apply_ordering, undo_ordering
from repro.core.cache_model import face_mask
from repro.core.neighbors import ring_perms
from repro.core.surfaces import surface_path_indices
from repro.kernels import ops
from repro.kernels import ref as kref

from .domain import STENCIL_AXES

__all__ = ["surface_slab_scatter", "halo_exchange_local", "make_distributed_step"]


@functools.lru_cache(maxsize=256)
def surface_slab_scatter(spec: OrderingSpec, M: int, g: int, face: str) -> np.ndarray:
    """Positions mapping a path-ordered face buffer into its (g,M,M)-like slab.

    ``slab.ravel()[pos[t]] = buf[t]`` reconstructs the face in canonical
    (row-major, face-local) layout. Works for any of the six faces; the
    slab spans the face's two free axes plus the g-width axis, in (k,i,j)
    order with the face axis collapsed to width g.
    """
    from repro.core.orderings import path_to_rmo

    q = path_to_rmo(spec, M)
    mask = face_mask(face, M, g)
    # rmo indices of face points, in path order (matches pack order)
    rmo = q[mask[q]]
    M2 = M * M
    k, i, j = rmo // M2, (rmo // M) % M, rmo % M
    ax, side = face[0], face[1]
    if ax == "k":
        kk = k if side == "0" else k - (M - g)
        pos = (kk * M + i) * M + j
    elif ax == "i":
        ii = i if side == "0" else i - (M - g)
        pos = (k * g + ii) * M + j
    else:
        jj = j if side == "0" else j - (M - g)
        pos = (k * M + i) * g + jj
    pos = pos.astype(np.int32)  # int32: M³ < 2³¹ (core.orderings._check_int32)
    pos.setflags(write=False)
    return pos


# neighbour conventions (ring partners) are shared with the block tables
_ring_perms = ring_perms


def _exchange_axis_slices(x: jnp.ndarray, axis_name: str, axis: int, g: int):
    """Corner-correct ring exchange along one axis via slicing."""
    n = jax.lax.psum(1, axis_name)
    fwd, bwd = _ring_perms(n)
    size = x.shape[axis]
    lo = jax.lax.slice_in_dim(x, 0, g, axis=axis)
    hi = jax.lax.slice_in_dim(x, size - g, size, axis=axis)
    recv_lo = jax.lax.ppermute(hi, axis_name, fwd)  # prev's high face
    recv_hi = jax.lax.ppermute(lo, axis_name, bwd)  # next's low face
    return jnp.concatenate([recv_lo, x, recv_hi], axis=axis)


def halo_exchange_local(state_path: jnp.ndarray, spec: OrderingSpec, M: int,
                        g: int, axis_names=STENCIL_AXES) -> jnp.ndarray:
    """Shard-local: path-ordered (M³,) state -> halo-extended (M+2g)³ cube.

    Axis 0 (slabs) uses the paper's list-based pack from the ordering;
    axes 1–2 extend the already-halo'd cube (corner-correct).
    """
    # --- paper-faithful pack of the k faces from the path-ordered state
    buf_k0 = ops.pack_surface(state_path, spec, M, g, "k0")
    buf_k1 = ops.pack_surface(state_path, spec, M, g, "k1")
    nx = jax.lax.psum(1, axis_names[0])
    fwd, bwd = _ring_perms(nx)
    recv_lo = jax.lax.ppermute(buf_k1, axis_names[0], fwd)
    recv_hi = jax.lax.ppermute(buf_k0, axis_names[0], bwd)
    # unpack buffers (path order) into canonical (g,M,M) slabs
    pos0 = jnp.asarray(surface_slab_scatter(spec, M, g, "k1"))  # lo halo = prev k1
    pos1 = jnp.asarray(surface_slab_scatter(spec, M, g, "k0"))  # hi halo = next k0
    slab_lo = jnp.zeros(g * M * M, state_path.dtype).at[pos0].set(recv_lo).reshape(g, M, M)
    slab_hi = jnp.zeros(g * M * M, state_path.dtype).at[pos1].set(recv_hi).reshape(g, M, M)
    cube = undo_ordering(state_path, spec, M)
    ext = jnp.concatenate([slab_lo, cube, slab_hi], axis=0)  # (M+2g, M, M)
    # --- remaining axes: slice-based, corner-correct
    ext = _exchange_axis_slices(ext, axis_names[1], 1, g)
    ext = _exchange_axis_slices(ext, axis_names[2], 2, g)
    return ext


def make_distributed_step(mesh: jax.sharding.Mesh, spec: OrderingSpec,
                          local_M: int, g: int):
    """jit'd distributed gol3d step on a sharded (P·M)³ global state.

    Global state layout: (px·M³, py, pz) is awkward; we use the flat form
    (px, py, pz, M³) — device (a,b,c) owns row [a,b,c] holding its local
    path-ordered state. Returns step(global_state) -> global_state.
    """
    pspec = P(*STENCIL_AXES)

    def local_step(state_path):  # (1,1,1,M³) per device
        s = state_path.reshape(-1)
        ext = halo_exchange_local(s, spec, local_M, g, STENCIL_AXES)
        # neighbour-sum stencil on the extended cube
        stot = 2 * g + 1
        acc = jnp.zeros((local_M,) * 3, jnp.float32)
        for dk in range(stot):
            for di in range(stot):
                for dj in range(stot):
                    acc = acc + ext[dk:dk + local_M, di:di + local_M,
                                    dj:dj + local_M].astype(jnp.float32)
        cube = ext[g:g + local_M, g:g + local_M, g:g + local_M]
        neigh = acc - cube.astype(jnp.float32)
        nxt = kref.gol_rule_ref(cube, neigh, g)
        return apply_ordering(nxt, spec).reshape(1, 1, 1, -1)

    step = shard_map(local_step, mesh=mesh, in_specs=pspec, out_specs=pspec)
    return jax.jit(step)
