"""Distributed halo exchange with SFC pack/unpack (paper §3.2/§4, on mesh).

The paper's halo pattern — pack faces into contiguous buffers via
precomputed index lists, exchange with neighbours, unpack — mapped to
JAX: ``shard_map`` over a 3D device mesh, ``jax.lax.ppermute`` ring
shifts per axis (axis-sequential, corner-correct).

Communication-avoiding form (DESIGN.md §7): each shard keeps its state
as the resident curve-ordered ``(nb, T, T, T)`` block store for the
whole K-step loop — the store *is* path-ordered state under the hybrid
ordering ``layout.store_spec(kind, T)``, so every face (not just the
slab axis) packs straight from storage via ``ops.pack_surface``. One
exchange moves *deep* faces of width ``h = S·g`` and funds S fused
substeps (same window-shrink math as ``stencil_step_fused``): the
received shell scatters into shell blocks appended after the core store
(core/neighbors.extended_neighbor_table addresses them), and the fused
kernel — or its jnp oracle — advances S whole timesteps per HBM
round-trip with no per-step ``undo_ordering``/``apply_ordering`` and no
canonical-cube materialisation, ever.

Multi-field stores (DESIGN.md §9): a C-channel workload keeps its state
as the stacked ``(C, nb, T, T, T)`` store. All C channels share one
block permutation and one set of face index lists, so a deep exchange
packs **every channel** into the same six messages — per-axis ICI
extents simply gain the ×C factor — and the shell scatter/extended
store carry the stacked axis through to the fused kernel unchanged.

On a TPU torus with Hilbert device ordering (launch/mesh.py) the six
ppermutes are single-hop ICI transfers.

Physical (clamped) boundaries — DESIGN.md §8: under a clamped
``core.boundary`` contract the rings are open (no wrap pairs, so no
ICI traffic across domain faces), mesh-edge shards fill their unserved
shell slabs with boundary values, and the fused substeps refresh ghost
layers per substep from the shard's mesh-masked block flags. A per-axis
``MixedBoundary`` opens only its clamped axes: periodic axes keep their
full rings, and the jaxpr carries ppermute pairs for those axes alone.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import OrderingSpec, path_to_rmo, rmo_to_path
from repro.core.boundary import (PERIODIC, BoundarySpec, MixedBoundary,
                                 as_boundary, axes_periodic)
from repro.core.cache_model import face_mask
from repro.core.layout import device_constant, store_spec
from repro.core.neighbors import (block_kind_of, boundary_face_table_device,
                                  extended_neighbor_table_device,
                                  ring_perms, shell_block_count)
from repro.core.surfaces import shell_slab_positions, shell_slab_shapes
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.ops import uniform_weights
from repro.kernels.rules import get_rule
from repro.kernels.stencil3d import stencil_step_fused

from .domain import STENCIL_AXES

__all__ = ["surface_slab_scatter", "exchange_shell", "shard_substeps",
           "shard_boundary_flags", "make_distributed_step",
           "stencil_block_kind", "shard_state", "unshard_state"]


@functools.lru_cache(maxsize=256)
def surface_slab_scatter(spec: OrderingSpec, M: int, g: int, face: str) -> np.ndarray:
    """Positions mapping a path-ordered face buffer into its (g,M,M)-like slab.

    ``slab.ravel()[pos[t]] = buf[t]`` reconstructs the face in canonical
    (row-major, face-local) layout. Works for any of the six faces and
    any width ``g`` (the deep exchange passes h = S·g); the slab spans
    the face's two free axes plus the g-width axis, in (k,i,j) order with
    the face axis collapsed to width g.
    """
    q = path_to_rmo(spec, M)
    mask = face_mask(face, M, g)
    # rmo indices of face points, in path order (matches pack order)
    rmo = q[mask[q]]
    M2 = M * M
    k, i, j = rmo // M2, (rmo // M) % M, rmo % M
    ax, side = face[0], face[1]
    if ax == "k":
        kk = k if side == "0" else k - (M - g)
        pos = (kk * M + i) * M + j
    elif ax == "i":
        ii = i if side == "0" else i - (M - g)
        pos = (k * g + ii) * M + j
    else:
        jj = j if side == "0" else j - (M - g)
        pos = (k * M + i) * g + jj
    pos = pos.astype(np.int32)  # int32: M³ < 2³¹ (core.orderings._check_int32)
    pos.setflags(write=False)
    return pos


def stencil_block_kind(spec: OrderingSpec) -> str:
    """Block-grid curve the stencil pipelines use for an element ordering:
    the ordering's own curve when it has one, else Morton (the pipelines
    are SFC-blocked even when the logical state ordering is row-major)."""
    kind = block_kind_of(spec)
    return kind if kind in ("morton", "hilbert") else "morton"


def _slab_scatter_device(spec: OrderingSpec, M: int, h: int, face: str):
    return device_constant(("slabscatter", spec, M, h, face),
                           lambda: surface_slab_scatter(spec, M, h, face))


def _pack_to_slab(store_flat, hspec, M, h, face, shape):
    """Pack one deep face from the (C, nb·T³) store, canonical slab layout."""
    buf = ops.pack_surface(store_flat, hspec, M, h, face)  # (C, L)
    pos = _slab_scatter_device(hspec, M, h, face)
    C = store_flat.shape[0]
    return jnp.zeros((C, h * M * M), buf.dtype).at[:, pos].set(buf) \
        .reshape((C,) + shape)


def _unpack_recv(buf, hspec, M, h, face, shape):
    """Scatter a received deep-face buffer (sender's pack order) into the
    canonical slab — sender and receiver share the index lists, so the
    receiver knows the order the remote pack produced."""
    pos = _slab_scatter_device(hspec, M, h, face)
    C = buf.shape[0]
    return jnp.zeros((C, h * M * M), buf.dtype).at[:, pos].set(buf) \
        .reshape((C,) + shape)


def _bc_face_fill(face: jnp.ndarray, axis: int, side: str,
                  bc: BoundarySpec) -> jnp.ndarray:
    """Boundary values for one shell slab of a clamped domain face.

    ``face`` is the slab the shard *would* send outward on that side
    (own deep face, already carrying any previously-filled edge data,
    with the leading channel axis); ``axis`` indexes the *spatial* axis
    (0..2) and ``bc`` is that axis's own contract (mixed runs pass each
    axis's spec). The returned array is what a mesh-edge shard holds in
    the ghost slab instead of exchanged data: the dirichlet constant, or
    — neumann0 — the outermost in-domain plane of ``face`` replicated
    across the slab's ``h`` width (clamp-copy), per channel.
    """
    if bc.kind == "dirichlet":
        return jnp.full(face.shape, bc.value, face.dtype)
    ax = axis - 3  # spatial axes are the last three (leading C rides along)
    edge = 0 if side == "lo" else face.shape[ax] - 1
    plane = jax.lax.slice_in_dim(face, edge, edge + 1, axis=ax)
    return jnp.broadcast_to(plane, face.shape)


def exchange_shell(store_flat: jnp.ndarray, kind: str, M: int, T: int,
                   h: int, axis_names=STENCIL_AXES, bc=PERIODIC):
    """Deep (width-h) corner-correct shell exchange from the block store.

    ``store_flat`` is the shard's ``(nb·T³,)`` ravelled curve-ordered
    block store — path-ordered state under ``store_spec(kind, T)``, so
    *all six* faces pack via the paper's precomputed index lists
    (ops.pack_surface), none from a materialised cube. A multi-field
    shard passes the stacked ``(C, nb·T³)`` store: every channel packs
    through the same index lists into the same six messages, so the
    per-axis ICI volume simply gains the ×C factor (DESIGN.md §9) and
    the returned slabs carry the leading channel axis. Axis-sequential
    scheme: the k faces are the bare M² surfaces; the i faces carry the
    k-received edges; the j faces carry both — after three ppermute
    rounds the six returned slabs tile the shell of the (M+2h)³ extended
    domain exactly (shapes: core/surfaces.shell_slab_shapes).

    Per-axis ICI volume is C·2h·M², C·2h·(M+2h)·M, C·2h·(M+2h)² items —
    the ``exchange_items_per_exchange`` model in stencil/pipeline.py.

    Clamped boundaries (core.boundary, DESIGN.md §8): each clamped axis
    ring is *open* — ``ring_perms(n, periodic=False)`` omits the
    wrapping pairs, so no bytes ever cross a clamped domain face — and
    mesh-edge shards substitute boundary values into the unserved slabs
    (dirichlet constant or neumann0 clamp-copy of their own outermost
    plane) before the next axis forwards them, which keeps corner
    regions composed exactly like the padded-cube oracle. Interior
    shards are untouched. A per-axis ``MixedBoundary`` opens only its
    clamped axes: the periodic axes keep full rings and wrap as on the
    torus, so the jaxpr carries ppermute pairs for those axes alone.
    """
    bc = as_boundary(bc)
    periodic = axes_periodic(bc)
    ax_bcs = bc.axes
    hspec = store_spec(kind, T)
    squeeze = store_flat.ndim == 1
    if squeeze:
        store_flat = store_flat[None]
    shp_k, _, shp_i, _, shp_j, _ = shell_slab_shapes(M, h)

    def _fill_edges(slab_lo, slab_hi, face_lo, face_hi, axis, ax_name):
        """On mesh-edge shards, replace received-zero slabs with BC data."""
        n = jax.lax.psum(1, ax_name)
        pos = jax.lax.axis_index(ax_name)
        slab_lo = jnp.where(pos == 0,
                            _bc_face_fill(face_lo, axis, "lo", ax_bcs[axis]),
                            slab_lo)
        slab_hi = jnp.where(pos == n - 1,
                            _bc_face_fill(face_hi, axis, "hi", ax_bcs[axis]),
                            slab_hi)
        return slab_lo, slab_hi

    # --- k axis: pack the deep slab faces, ring-shift, unpack
    buf_k0 = ops.pack_surface(store_flat, hspec, M, h, "k0")
    buf_k1 = ops.pack_surface(store_flat, hspec, M, h, "k1")
    fwd, bwd = ring_perms(jax.lax.psum(1, axis_names[0]), periodic=periodic[0])
    recv_lo = jax.lax.ppermute(buf_k1, axis_names[0], fwd)  # prev's high face
    recv_hi = jax.lax.ppermute(buf_k0, axis_names[0], bwd)  # next's low face
    slab_k_lo = _unpack_recv(recv_lo, hspec, M, h, "k1", shp_k)
    slab_k_hi = _unpack_recv(recv_hi, hspec, M, h, "k0", shp_k)
    if not periodic[0]:
        own_k0 = _pack_to_slab(store_flat, hspec, M, h, "k0", shp_k)
        own_k1 = _pack_to_slab(store_flat, hspec, M, h, "k1", shp_k)
        slab_k_lo, slab_k_hi = _fill_edges(slab_k_lo, slab_k_hi,
                                           own_k0, own_k1, 0, axis_names[0])

    # --- i axis: core faces + k-received edges (corner-correct)
    my_i0 = _pack_to_slab(store_flat, hspec, M, h, "i0", (M, h, M))
    my_i1 = _pack_to_slab(store_flat, hspec, M, h, "i1", (M, h, M))
    face_i0 = jnp.concatenate(
        [slab_k_lo[..., :h, :], my_i0, slab_k_hi[..., :h, :]], axis=-3)
    face_i1 = jnp.concatenate(
        [slab_k_lo[..., M - h:, :], my_i1, slab_k_hi[..., M - h:, :]], axis=-3)
    fwd, bwd = ring_perms(jax.lax.psum(1, axis_names[1]), periodic=periodic[1])
    slab_i_lo = jax.lax.ppermute(face_i1, axis_names[1], fwd)
    slab_i_hi = jax.lax.ppermute(face_i0, axis_names[1], bwd)
    if not periodic[1]:
        slab_i_lo, slab_i_hi = _fill_edges(slab_i_lo, slab_i_hi,
                                           face_i0, face_i1, 1, axis_names[1])
    assert slab_i_lo.shape[-3:] == shp_i, (slab_i_lo.shape, shp_i)

    # --- j axis: core faces + both received edge sets
    my_j0 = _pack_to_slab(store_flat, hspec, M, h, "j0", (M, M, h))
    my_j1 = _pack_to_slab(store_flat, hspec, M, h, "j1", (M, M, h))

    def _j_face(mine, sl):
        mid = jnp.concatenate(
            [slab_k_lo[..., sl], mine, slab_k_hi[..., sl]], axis=-3)
        return jnp.concatenate(
            [slab_i_lo[..., sl], mid, slab_i_hi[..., sl]], axis=-2)

    face_j0 = _j_face(my_j0, slice(0, h))
    face_j1 = _j_face(my_j1, slice(M - h, M))
    fwd, bwd = ring_perms(jax.lax.psum(1, axis_names[2]), periodic=periodic[2])
    slab_j_lo = jax.lax.ppermute(face_j1, axis_names[2], fwd)
    slab_j_hi = jax.lax.ppermute(face_j0, axis_names[2], bwd)
    if not periodic[2]:
        slab_j_lo, slab_j_hi = _fill_edges(slab_j_lo, slab_j_hi,
                                           face_j0, face_j1, 2, axis_names[2])
    assert slab_j_lo.shape[-3:] == shp_j, (slab_j_lo.shape, shp_j)

    slabs = (slab_k_lo, slab_k_hi, slab_i_lo, slab_i_hi, slab_j_lo, slab_j_hi)
    return tuple(s[0] for s in slabs) if squeeze else slabs


def _shell_positions_device(nt: int, T: int, h: int):
    return device_constant(("shellpos", nt, T, h),
                           lambda: shell_slab_positions(nt, T, h))


def shard_boundary_flags(kind: str, nt: int,
                         axis_names=STENCIL_AXES) -> jnp.ndarray:
    """(nb, 6) clamped-domain-face flags for this shard's blocks.

    The base table (core.neighbors.boundary_face_table) marks blocks on
    the *local* grid edge; a face is a physical domain face only when
    the shard also sits on the mesh edge of that axis, so each column is
    AND-masked with the shard's position read off the shard_map axes
    (axis_names order (dx, dy, dz) ↔ face columns (k∓, i∓, j∓)). On
    mixed contracts the refresh (rules.apply_window_bc) skips periodic
    axes by itself, so the table needs no further bc masking.
    """
    base = jnp.asarray(boundary_face_table_device(kind, nt))
    edge = []
    for ax in axis_names:
        n = jax.lax.psum(1, ax)
        pos = jax.lax.axis_index(ax)
        edge += [pos == 0, pos == n - 1]
    return base * jnp.stack(edge).astype(jnp.int32)[None, :]


def shard_substeps(store: jnp.ndarray, *, kind: str, M: int, g: int, S: int,
                   rule: str = "gol", bc: BoundarySpec | MixedBoundary | str = PERIODIC,
                   use_kernel: bool = False, interpret: bool = True,
                   axis_names=STENCIL_AXES) -> jnp.ndarray:
    """One deep exchange + S fused substeps on the resident shard store.

    store: (nb, T, T, T) curve-ordered local block store (shard_map
    body), or the stacked multi-field ``(C, nb, T, T, T)`` store when
    the rule declares C > 1 (DESIGN.md §9). Exchanges width S·g once —
    all C channels in the same six messages — scatters the shell into
    shell blocks appended after the core, and runs S whole timesteps
    through ``stencil_step_fused`` (or its jnp oracle) with the extended
    neighbour table — the distributed counterpart of one
    ResidentPipeline launch. S sequential S=1 calls are bit-identical
    (f32) to one S-deep call, same argument as the fused kernel.

    On clamped runs (``bc``, core.boundary — uniform or per-axis mixed)
    the exchange fills mesh-edge shell blocks with boundary values
    instead of ppermuted ghost data, and the fused substeps refresh
    those ghost layers per substep via the shard's mesh-masked face
    flags (:func:`shard_boundary_flags`) — so the deep rounds stay
    bit-identical to S sequential clamped steps.
    """
    multi = store.ndim == 5
    nb, T = store.shape[-4], store.shape[-3]
    nt = M // T
    assert nb == nt ** 3, (store.shape, M)
    bc = as_boundary(bc)
    h = S * g
    flat = store.reshape(store.shape[0], -1) if multi else store.reshape(-1)
    slabs = exchange_shell(flat, kind, M, T, h, axis_names, bc=bc)
    pos = _shell_positions_device(nt, T, h)
    if multi:
        C = store.shape[0]
        vals = jnp.concatenate([s.reshape(C, -1) for s in slabs], axis=1)
        shell = jnp.zeros((C, shell_block_count(nt) * T ** 3), store.dtype
                          ).at[:, pos].set(vals).reshape(C, -1, T, T, T)
        ext = jnp.concatenate([store, shell], axis=1)
    else:
        vals = jnp.concatenate([s.reshape(-1) for s in slabs])
        shell = jnp.zeros((shell_block_count(nt) * T ** 3,), store.dtype
                          ).at[pos].set(vals).reshape(-1, T, T, T)
        ext = jnp.concatenate([store, shell], axis=0)
    nbr = extended_neighbor_table_device(kind, nt)
    bnd = shard_boundary_flags(kind, nt, axis_names) if bc.clamped else None
    w = uniform_weights(g)
    if use_kernel:
        return stencil_step_fused(ext, w, nbr, bnd, g=g, S=S, rule=rule,
                                  bc=bc, interpret=interpret)
    return kref.stencil_fused_ref(ext, w, nbr, S=S, rule=rule, bc=bc, bnd=bnd)


def _store_perm(spec: OrderingSpec, kind: str, T: int, M: int,
                inverse: bool) -> np.ndarray:
    """Permutation between spec-path-ordered state and the block store.

    Forward: ``store_flat = state_path[perm]``; inverse:
    ``state_path = store_flat[perm_inv]``. Composition of the two
    orderings' permutations — applied once per K-step run (the layout
    boundary), never per step.
    """
    hspec = store_spec(kind, T)
    if inverse:
        return rmo_to_path(hspec, M)[path_to_rmo(spec, M)]
    return rmo_to_path(spec, M)[path_to_rmo(hspec, M)]


def _store_perm_device(spec: OrderingSpec, kind: str, T: int, M: int,
                       inverse: bool):
    return device_constant(("storeperm", spec, kind, T, M, inverse),
                           lambda: _store_perm(spec, kind, T, M, inverse))


def _state_pspec(channels: int) -> P:
    """shard_map spec of the public sharded state: (px, py, pz, M³) for
    C=1, (px, py, pz, C, M³) for a multi-field workload — the channel
    axis is replicated across the mesh (it lives inside every shard)."""
    return P(*STENCIL_AXES) if channels == 1 else P(*STENCIL_AXES, None)


def make_distributed_step(mesh: jax.sharding.Mesh, spec: OrderingSpec,
                          local_M: int, g: int, *, T: int | None = None,
                          rule: str = "gol", bc: BoundarySpec | MixedBoundary | str = PERIODIC,
                          use_kernel: bool = False, interpret: bool = True):
    """jit'd distributed stencil step on a sharded (P·M)³ global state.

    Global state layout: (px, py, pz, M³) — device (a,b,c) owns row
    [a,b,c] holding its local path-ordered state under ``spec``
    (see :func:`shard_state`). A multi-field rule (C > 1) uses
    (px, py, pz, C, M³): the C channels ride inside every shard, each
    path-ordered under the same ``spec``. ``bc`` selects the boundary
    contract (core.boundary: periodic | dirichlet | neumann0 | mixed).
    Returns step(global_state) -> global_state.

    The legacy per-step reference for DistributedPipeline (which runs the
    same :func:`shard_substeps` round at depth S): no per-step full-cube
    repack — the state converts to the block store and back (one
    permutation gather each way), all six faces pack from the store via
    the index lists, and the compute is the fused S=1 path. Bit-identical
    to the pipeline at every S (f32), and to the pre-rebuild slice-loop
    reference for integer-valued rules (gol).
    """
    if T is None:
        T = min(8, local_M)
    C = get_rule(rule).channels
    pspec = _state_pspec(C)
    kind = stencil_block_kind(spec)
    nt = local_M // T

    def local_step(state_path):  # (1,1,1,[C,]M³) per device
        if C == 1:
            s = state_path.reshape(-1)
            store = s[_store_perm_device(spec, kind, T, local_M, False)]
            store = store.reshape(nt ** 3, T, T, T)
        else:
            s = state_path.reshape(C, -1)
            store = jnp.take(s, _store_perm_device(spec, kind, T, local_M,
                                                   False), axis=-1)
            store = store.reshape(C, nt ** 3, T, T, T)
        store = shard_substeps(store, kind=kind,
                               M=local_M, g=g, S=1, rule=rule, bc=bc,
                               use_kernel=use_kernel, interpret=interpret)
        if C == 1:
            out = store.reshape(-1)[_store_perm_device(spec, kind, T,
                                                       local_M, True)]
            return out.reshape(1, 1, 1, -1)
        out = jnp.take(store.reshape(C, -1),
                       _store_perm_device(spec, kind, T, local_M, True),
                       axis=-1)
        return out.reshape(1, 1, 1, C, -1)

    # check_rep=False: pallas_call has no shard_map replication rule yet
    step = shard_map(local_step, mesh=mesh, in_specs=pspec, out_specs=pspec,
                     check_rep=False)
    return jax.jit(step)


# ----------------------------------------------------------------------
# Global-state layout helpers (tests, demos, Gol3d.run_distributed)
# ----------------------------------------------------------------------

def shard_state(cube: jnp.ndarray, spec: OrderingSpec,
                procs: tuple[int, int, int]) -> jnp.ndarray:
    """(Gk,Gi,Gj) canonical state -> (px,py,pz,M³) per-shard path state.

    Stacked multi-field input (C,Gk,Gi,Gj) -> (px,py,pz,C,M³): every
    channel shards identically and is path-ordered under ``spec``.
    The global box may be non-cubic (a 4×2×1 mesh decomposes a
    (4M, 2M, M) domain, DESIGN.md §10) — only the *local* shard must be
    a cubic power-of-2 block, because that is what the SFC machinery
    orders.
    """
    from repro.core.layout import _perm_device

    squeeze = cube.ndim == 3
    if squeeze:
        cube = cube[None]
    C = cube.shape[0]
    gk, gi, gj = cube.shape[1:]
    px, py, pz = procs
    if gk % px or gi % py or gj % pz:
        raise ValueError(f"global shape {(gk, gi, gj)} does not divide "
                         f"over procs {procs}")
    lk, li, lj = gk // px, gi // py, gj // pz
    if not (lk == li == lj):
        raise ValueError(f"local block must be cubic, got {(lk, li, lj)} "
                         f"from global {(gk, gi, gj)} over procs {procs}")
    parts = cube.reshape(C, px, lk, py, li, pz, lj) \
        .transpose(1, 3, 5, 0, 2, 4, 6)  # (px,py,pz,C,lk,li,lj)
    q = _perm_device(spec, lk, False)  # path pos -> rmo (apply_ordering)
    out = jnp.take(parts.reshape(px, py, pz, C, -1), q, axis=-1)
    return out[:, :, :, 0] if squeeze else out


def unshard_state(state: jnp.ndarray, spec: OrderingSpec,
                  global_M=None) -> jnp.ndarray:
    """Inverse of :func:`shard_state` (C-stacked state comes back as
    (C, Gk, Gi, Gj)). ``global_M`` — a cube edge or (Gk,Gi,Gj) triple —
    is optional: the global box is derivable from the state shape and
    the argument is only checked against it when given."""
    from repro.core.layout import _perm_device

    squeeze = state.ndim == 4
    if squeeze:
        state = state[:, :, :, None]
    px, py, pz, C = state.shape[:4]
    lk = round(state.shape[4] ** (1 / 3))
    lk = next(m for m in (lk - 1, lk, lk + 1) if m ** 3 == state.shape[4])
    shape = (px * lk, py * lk, pz * lk)
    if global_M is not None:
        want = (global_M,) * 3 if isinstance(global_M, int) else tuple(global_M)
        if want != shape:
            raise ValueError(f"state {state.shape} implies global {shape}, "
                             f"caller said {want}")
    p = _perm_device(spec, lk, True)  # rmo -> path pos (undo_ordering)
    parts = jnp.take(state, p, axis=-1).reshape(px, py, pz, C, lk, lk, lk)
    out = parts.transpose(3, 0, 4, 1, 5, 2, 6).reshape(C, *shape)
    return out[0] if squeeze else out
