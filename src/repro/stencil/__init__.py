"""The paper's stencil application: gol3d + distributed halo exchange."""

from .gol3d import Gol3d, Gol3dConfig  # noqa: F401
from .pipeline import (  # noqa: F401
    DistributedPipeline, ResidentPipeline, VMEM_BUDGET_BYTES,
    checkpoint_bytes_per_interval, checkpoint_traffic_fraction,
    distributed_bytes_per_step, exchange_bytes_per_step, exchange_face_items,
    exchange_items_per_exchange, fused_items_per_launch, fused_vmem_bytes,
    repack_bytes_per_step, repack_items_per_step, resident_bytes_per_step,
    resident_unfused_bytes_per_step, resident_unfused_items_per_step,
)
from .domain import Decomposition3D, make_stencil_mesh, STENCIL_AXES  # noqa: F401
from .halo import (  # noqa: F401
    exchange_shell, make_distributed_step, shard_boundary_flags, shard_state,
    shard_substeps, stencil_block_kind, surface_slab_scatter, unshard_state,
)
from .runner import (  # noqa: F401
    CheckpointedRun, RunHealthError, RunHooks, health_check,
)
