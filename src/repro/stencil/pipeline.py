"""Fused resident-block-store stencil driver (DESIGN.md §3–§4, §9).

The paper's central claim is that SFC orderings pay off only when the
curve order *is* the storage order — reorder once, iterate many times
(§2, §4 of the paper). This driver enforces that discipline for the
stencil workloads:

    blockize once  →  K timesteps entirely in curve-ordered block form
                      (halo assembled in-kernel from the neighbour
                      tables, never materialised in HBM)
                   →  unblockize once.

The per-step state is exactly one ``(C, nb, T, T, T)`` block store — C
channels of M³ elements, one shared block permutation, no
``((T+2g)/T)³`` halo duplication (C=1 workloads keep the plain
``(nb, T, T, T)`` form) — and consecutive launches ping-pong between
two such stores: the K-step runner is jit'd with the input store
donated, so XLA aliases the output of launch k as the input of launch
k+1 (classic double buffering) instead of allocating per step.

Temporal blocking (DESIGN.md §4): with ``S`` substeps per launch the
kernel assembles a ``(T+2·S·g)³`` window per channel and runs S whole
tap-sum + update-rule substeps in VMEM before writing the C·T³ tiles
once — K timesteps become ``ceil(K/S)`` HBM round-trips. ``plan()``
autotunes (T, S) by minimising the modelled bytes/substep under the
VMEM budget, with every term carrying the rule's channel count.

The ``*_items_per_*`` helpers are the single source of HBM-traffic
accounting shared by benchmarks/stencil_update.py and
benchmarks/kernel_bench.py (asserted consistent in tests); their
``fields`` keyword is the ×C factor of the multi-field store
(DESIGN.md §9).

Both pipelines carry a boundary contract (``bc``, core.boundary —
DESIGN.md §8): clamped runs swap in the non-wrapping neighbour tables,
refresh ghost layers per substep, open the exchange rings (the clamped
keywords of the exchange-bytes helpers model the smaller surface), and
stay bit-identical (f32) between the S-deep and sequential forms
exactly like the periodic case. A per-axis ``MixedBoundary`` (clamped k,
periodic i/j, …) threads through identically: only its clamped axes
open.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.core.boundary import (PERIODIC, BoundarySpec, MixedBoundary,
                                 as_boundary, axes_periodic)
from repro.core.layout import (blockize, blockize_fields, unblockize,
                               unblockize_fields)
from repro.core.neighbors import (boundary_face_table_device,
                                  neighbor_table_device)
from repro.core.orderings import OrderingSpec
from repro.kernels import ref as kref
from repro.kernels.ops import uniform_weights
from repro.kernels.rules import get_rule
from repro.kernels.stencil3d import stencil_step_fused

from .domain import STENCIL_AXES
from .halo import (shard_substeps, shard_state, stencil_block_kind,
                   unshard_state, _state_pspec, _store_perm_device)

__all__ = [
    "ResidentPipeline", "DistributedPipeline", "VMEM_BUDGET_BYTES",
    "fused_vmem_bytes",
    "repack_items_per_step", "repack_bytes_per_step",
    "fused_items_per_launch", "resident_bytes_per_step",
    "resident_unfused_items_per_step", "resident_unfused_bytes_per_step",
    "exchange_face_items", "exchange_items_per_exchange",
    "exchange_bytes_per_step", "distributed_bytes_per_step",
    "checkpoint_bytes_per_interval", "checkpoint_traffic_fraction",
]

# Conservative per-core VMEM working-set budget the autotuner plans
# against (real TPU cores have ~16 MiB; leave half for Pallas' pipeline
# buffers, metadata, and the scalar-prefetch tables).
VMEM_BUDGET_BYTES = 8 * 2 ** 20


@dataclass(frozen=True)
class ResidentPipeline:
    """Stencil updates over a persistent curve-ordered block store.

    M:          cube edge (power of 2)
    T:          block edge (T | M; S·g | T for the kernel path)
    g:          stencil radius
    kind:       block-grid curve — "morton" | "hilbert" | "row_major" |
                "column_major" (core.neighbors.block_kind_of maps an
                OrderingSpec here)
    S:          substeps fused into one kernel launch (temporal blocking)
    rule:       update rule registry key (kernels/rules.py). The rule's
                declared ``channels`` (C) selects the store form: C=1
                rules run the plain ``(nb, T³)`` store, multi-field
                rules (``wave``) the stacked ``(C, nb, T³)`` store
                (DESIGN.md §9) — same curve, same neighbour tables.
    bc:         boundary contract (core.boundary.BoundarySpec, a kind
                string, or a per-axis MixedBoundary): "periodic"
                (default, torus) | "dirichlet" | "neumann0". Clamped
                runs use the non-wrapping neighbour table (per axis for
                mixed contracts) and refresh ghost layers per substep —
                temporal blocking stays exactly as deep at domain edges
                (DESIGN.md §8).
    use_kernel: Pallas fused kernel (interpret on CPU) vs jnp oracle

    Every knob is a static (hashable) field: a pipeline instance is both
    the configuration and the jit cache key of its runners.
    """
    M: int
    T: int = 8
    g: int = 1
    kind: str = "morton"
    use_kernel: bool = False
    interpret: bool = True
    S: int = 1
    rule: str = "gol"
    bc: BoundarySpec | MixedBoundary = PERIODIC

    def __post_init__(self):
        object.__setattr__(self, "bc", as_boundary(self.bc))
        assert self.M % self.T == 0, (self.M, self.T)
        if not self._valid_S(self.S):
            raise ValueError(
                f"temporal blocking needs 1 <= S*g <= T and S*g | T, "
                f"got T={self.T}, g={self.g}, S={self.S}")

    def _valid_S(self, S: int) -> bool:
        h = S * self.g
        return S >= 1 and h <= self.T and self.T % h == 0

    @property
    def nt(self) -> int:
        return self.M // self.T

    @property
    def nb(self) -> int:
        return self.nt ** 3

    @property
    def channels(self) -> int:
        """C of the rule's store — the ×C factor of every byte model."""
        return get_rule(self.rule).channels

    # -- autotuner ---------------------------------------------------------
    @classmethod
    def plan(cls, M: int, g: int = 1, kind: str = "morton",
             rule: str = "gol", n_steps: int = 10, *,
             bc: BoundarySpec | MixedBoundary | str = PERIODIC,
             vmem_limit: int = VMEM_BUDGET_BYTES, max_S: int = 8,
             use_kernel: bool = False, interpret: bool = True,
             itemsize: int = 4) -> "ResidentPipeline":
        """Pick (T, S) minimising modelled HBM bytes/substep under VMEM.

        Searches power-of-two block edges T | M (with g | T) and substep
        counts S ≤ max_S (with S·g | T), keeps candidates whose fused
        working set fits ``vmem_limit``, and minimises
        ``resident_bytes_per_step(M, T, g, n_steps, S=S, fields=C)``.
        The cost is non-monotone in S at fixed T — window inflation
        (T+2·S·g)³/S eventually out-grows the S× amortisation — so this
        is a real search, not "largest S that fits". Ties break toward
        smaller windows. A multi-field rule scales both the stream and
        the VMEM working set by its C, so the same budget admits
        shallower windows (DESIGN.md §9). ``bc`` threads through to the
        pipeline unchanged: the single-device HBM stream is
        boundary-independent (clamped runs trade wrapped halo reads for
        in-window substitution, same window), so the plan itself does
        not shift.
        """
        C = get_rule(rule).channels
        T, S = _plan_search(
            M, g, max_S, vmem_limit, itemsize,
            lambda T, S: resident_bytes_per_step(M, T, g, n_steps,
                                                 itemsize, S=S, fields=C),
            fields=C)
        return cls(M=M, T=T, g=g, kind=kind, S=S, rule=rule, bc=bc,
                   use_kernel=use_kernel, interpret=interpret)

    # -- layout boundary (paid once per K-step run, not per step) ---------
    def to_blocks(self, cube: jnp.ndarray) -> jnp.ndarray:
        """Blockize the canonical state: an (M,M,M) cube for C=1 rules,
        stacked (C,M,M,M) fields for multi-field rules — one shared
        block permutation either way."""
        if cube.ndim == 3:
            return blockize(cube, self.T, kind=self.kind)
        return blockize_fields(cube, self.T, kind=self.kind)

    def to_cube(self, store: jnp.ndarray) -> jnp.ndarray:
        if store.ndim == 4:
            return unblockize(store, self.M, kind=self.kind)
        return unblockize_fields(store, self.M, kind=self.kind)

    # -- the resident step -------------------------------------------------
    def step_fn(self, substeps: int | None = None):
        """(store -> store): ``substeps`` (default S) fused updates.

        Kernel mode is one ``stencil_step_fused`` launch; oracle mode is
        the same math as sequential jnp substeps — bit-identical for f32
        stores (substeps accumulate in f32 on both paths). Clamped runs
        feed the non-wrapping neighbour table (per-axis for mixed
        contracts) plus the block boundary flags; the per-substep ghost
        refresh lives in the shared kernels/rules.apply_window_bc helper
        on both paths.
        """
        S = self.S if substeps is None else substeps
        assert self._valid_S(S), (self.T, self.g, S)
        g, bc, w = self.g, self.bc, uniform_weights(self.g)
        nbr = neighbor_table_device(self.kind, self.nt,
                                    periodic=axes_periodic(bc))
        bnd = boundary_face_table_device(self.kind, self.nt) \
            if bc.clamped else None
        rule = get_rule(self.rule)
        use_kernel, interpret = self.use_kernel, self.interpret

        def step(store):
            if use_kernel:
                return stencil_step_fused(store, w, nbr, bnd, g=g, S=S,
                                          rule=rule.name, bc=bc,
                                          interpret=interpret)
            out = store
            for _ in range(S):
                out = kref.stencil_fused_ref(out, w, nbr, S=1,
                                             rule=rule, bc=bc, bnd=bnd)
            return out

        return step

    def run_fn(self, n_steps: int):
        """jit'd K-step runner: ceil(K/S) fused launches over the donated
        (double-buffered) store; a K % S remainder runs as one smaller
        fused launch when S·g-divisibility allows, else step by step."""
        full, rem = divmod(n_steps, self.S)
        step = self.step_fn()
        if rem and self._valid_S(rem):
            tail_steps, tail = 1, self.step_fn(rem)
        else:
            tail_steps, tail = rem, (self.step_fn(1) if rem else None)
        donate = (0,) if jax.default_backend() != "cpu" else ()

        @functools.partial(jax.jit, donate_argnums=donate)
        def run(store):
            if full:
                store = jax.lax.fori_loop(0, full, lambda _, s: step(s), store)
            if tail is not None:
                store = jax.lax.fori_loop(0, tail_steps,
                                          lambda _, s: tail(s), store)
            return store

        return run

    def run(self, cube: jnp.ndarray, n_steps: int) -> jnp.ndarray:
        """blockize once → n_steps fused curve-ordered updates → unblockize.

        ``cube`` is (M,M,M) for C=1 rules, stacked (C,M,M,M) for
        multi-field rules; the return matches.
        """
        store = self.to_blocks(cube)
        store = self.run_fn(n_steps)(store)
        return self.to_cube(store)

    # -- modelled HBM traffic (benchmarks/stencil_update.py) ---------------
    def bytes_per_step(self, n_steps: int, itemsize: int = 4) -> float:
        return resident_bytes_per_step(self.M, self.T, self.g, n_steps,
                                       itemsize, S=self.S,
                                       fields=self.channels)

    def vmem_bytes(self, itemsize: int = 4) -> int:
        return fused_vmem_bytes(self.T, self.g, self.S, itemsize,
                                fields=self.channels)


def _plan_search(M: int, g: int, max_S: int, vmem_limit: int, itemsize: int,
                 cost_fn, fields: int = 1) -> tuple[int, int]:
    """Enumerate valid power-of-two (T, S) under the VMEM budget and pick
    the ``cost_fn(T, S)``-cheapest pair (ties toward smaller windows) —
    the one search behind both the resident and the distributed plan.
    ``fields`` scales the modelled working set (multi-field stores keep
    C windows live)."""
    best = None
    T = 1
    while T <= M:
        if M % T == 0 and T % g == 0:
            S = 1
            while S <= max_S:
                h = S * g
                if h <= T and T % h == 0:
                    vm = fused_vmem_bytes(T, g, S, itemsize, fields=fields)
                    if vm <= vmem_limit:
                        cost = cost_fn(T, S)
                        if best is None or (cost, vm) < best[0]:
                            best = ((cost, vm), T, S)
                S *= 2
        T *= 2
    if best is None:
        raise ValueError(
            f"no (T, S) fits vmem_limit={vmem_limit} for M={M}, g={g}, "
            f"fields={fields}")
    return best[1], best[2]


def fused_vmem_bytes(T: int, g: int, S: int, itemsize: int = 4, *,
                     fields: int = 1) -> int:
    """Modelled VMEM working set of one fused-kernel grid step.

    Two window-sized live arrays per channel (the assembled window plus
    the tap/rule temporary), the C·T³ output tile double-buffered, and
    the tap weights (shared across channels).
    """
    W3 = (T + 2 * S * g) ** 3
    return itemsize * (fields * (2 * W3 + 2 * T ** 3) + (2 * g + 1) ** 3)


# ---------------------------------------------------------------------------
# HBM-traffic accounting — the one source of truth for every benchmark row.
# ``*_items_per_*`` count array elements; ``*_bytes_per_step`` scale by
# itemsize and amortise the one-off layout boundary over the run. The
# ``fields`` keyword is the multi-field ×C factor (DESIGN.md §9): a
# C-channel store streams C windows in and C tiles out per block, packs C
# channels per exchanged face, and blockizes C cubes at the run boundary.
# ---------------------------------------------------------------------------

def repack_items_per_step(M: int, T: int, g: int) -> int:
    """HBM items per step of the repack pipeline (ops.gol3d_step).

    Every step: read the M³ cube, write the halo-duplicated (nb·(T+2g)³)
    store, stream it back through the kernel, write nb·T³ partial sums,
    then read them again (plus the centre) for the rule and write the
    canonical cube back. The ((T+2g)/T)³ inflation and the O(M³) repack
    recur each step.
    """
    nb = (M // T) ** 3
    W3 = (T + 2 * g) ** 3
    cube, halo, out = M ** 3, nb * W3, nb * T ** 3
    #      repack read + halo write + kernel read + kernel write
    #      + rule read/write + unblockize read + cube write
    return cube + halo + halo + out + 2 * out + out + cube


def repack_bytes_per_step(M: int, T: int, g: int, itemsize: int = 4) -> float:
    return itemsize * float(repack_items_per_step(M, T, g))


def resident_unfused_items_per_step(M: int, T: int, g: int) -> int:
    """HBM items per step of the PR-1 resident path (pre-fusion baseline).

    The kernel reads (T+2g)³ per block and writes an f32 tap-sum array;
    a separate rule pass then reads store+sums and writes the next store
    — 2·T³ per block beyond the kernel stream, every step.
    """
    nb = (M // T) ** 3
    return nb * (T + 2 * g) ** 3 + 3 * nb * T ** 3


def resident_unfused_bytes_per_step(M: int, T: int, g: int, n_steps: int,
                                    itemsize: int = 4) -> float:
    per_step = resident_unfused_items_per_step(M, T, g)
    return itemsize * (per_step + _boundary_items(M) / max(n_steps, 1))


def fused_items_per_launch(M: int, T: int, g: int, S: int, *,
                           fields: int = 1) -> int:
    """HBM items of one fused launch: read C·(T+2·S·g)³ + write C·T³ per
    block — every channel streams its window and tile (DESIGN.md §9).

    No tap-sum array, no rule pass — S substeps ride one round-trip.
    """
    nb = (M // T) ** 3
    return fields * (nb * (T + 2 * S * g) ** 3 + nb * T ** 3)


def resident_bytes_per_step(M: int, T: int, g: int, n_steps: int,
                            itemsize: int = 4, *, S: int = 1,
                            fields: int = 1) -> float:
    """Modelled HBM bytes per timestep of the fused resident pipeline.

    The unit is unchanged from PR-1: one whole timestep of the workload
    (a "substep" of a fused launch is a full timestep; a multi-field
    timestep advances all C channels, hence the ×C stream). One launch
    advances S of them, so the per-launch stream amortises by S; the
    one-off blockize/unblockize (read C·M³ + write C·M³ each) amortises
    over the whole K-step run.
    """
    per_substep = fused_items_per_launch(M, T, g, S, fields=fields) / S
    return itemsize * (per_substep
                       + fields * _boundary_items(M) / max(n_steps, 1))


def _boundary_items(M: int) -> int:
    # blockize + unblockize: read M³ + write M³ each, once per run
    return 4 * M ** 3


def checkpoint_bytes_per_interval(M, *, fields: int = 1,
                                  itemsize: int = 4) -> int:
    """Bytes one checkpoint writes: the canonical (curve-independent)
    C-channel state of an M³ cube — or a non-cubic (Gk,Gi,Gj) box —
    once per interval (stencil/runner.CheckpointedRun, DESIGN.md §10).

    The snapshot is the *logical* state, so its size is ordering-, T-,
    S- and mesh-independent: exactly ``C · ∏(shape) · itemsize`` payload
    bytes (the npz container and manifest add O(KiB), not modelled).
    """
    gk, gi, gj = (M, M, M) if isinstance(M, int) else M
    return fields * gk * gi * gj * itemsize


def checkpoint_traffic_fraction(M: int, T: int, g: int, interval: int, *,
                                S: int = 1, fields: int = 1,
                                itemsize: int = 4) -> float:
    """Modelled fraction of per-interval data movement spent on the
    checkpoint: snapshot bytes (plus the unblockize read that produces
    the canonical state) over snapshot + the interval's fused HBM
    stream. The denominator uses the same shared accounting as every
    benchmark row — this is the number the measured wall fraction in
    benchmarks/stencil_update.py is compared against."""
    snap = checkpoint_bytes_per_interval(M, fields=fields, itemsize=itemsize) \
        + fields * M ** 3 * itemsize  # unblockize read of the store
    compute = interval * fused_items_per_launch(M, T, g, S, fields=fields) \
        / S * itemsize
    return snap / (snap + compute)


def exchange_face_items(M: int, g: int, S: int = 1) -> tuple[int, int, int]:
    """Per-axis items of ONE sent face at exchange depth h = S·g (single
    channel — the exchange helpers apply the ×C ``fields`` factor).

    Axis-sequential corner-correct extents (stencil/halo.exchange_shell):
    the k faces are bare h·M² slabs, the i faces carry the k-received
    edges (h·(M+2h)·M), the j faces both (h·(M+2h)²). These are exactly
    the packed slab shapes (core/surfaces.shell_slab_shapes) — asserted
    equal in tests — so the model *is* the wire format.
    """
    h = S * g
    e = M + 2 * h
    return (h * M * M, h * e * M, h * e * e)


def exchange_items_per_exchange(M: int, g: int, S: int = 1, *,
                                bc: BoundarySpec | MixedBoundary | str = PERIODIC,
                                procs: tuple[int, int, int] | None = None,
                                coords: tuple[int, int, int] | None = None,
                                fields: int = 1) -> float:
    """ICI items one shard moves per deep halo exchange (h = S·g).

    Periodic (default): every shard sends both faces on all three axes —
    ``C·2h·[M² + (M+2h)·M + (M+2h)²]`` items (C = ``fields``: every
    channel packs into the same messages, DESIGN.md §9). Deep halos
    therefore move *slightly more* bytes in total (the corner terms grow
    with h) — what S buys is S× fewer exchanges (latency/launch
    amortisation) and the fused kernel's HBM amortisation, the
    communication-avoiding trade.

    Clamped (``bc`` dirichlet/neumann0, or a per-axis mixed contract):
    clamped-axis rings are open, so a send happens only where a
    neighbour exists — pass the mesh shape ``procs`` and either a
    shard's mesh ``coords`` (that shard's exact items: each clamped axis
    contributes its face size once per existing neighbour, so mesh-edge
    shards move strictly fewer bytes than the periodic torus) or
    ``coords=None`` for the mesh-wide mean (``2(p-1)/p`` faces per
    clamped axis — the smaller exchange surface
    DistributedPipeline.plan() minimises). Periodic axes of a mixed
    contract keep the full 2-face volume.
    """
    sizes = exchange_face_items(M, g, S)
    periodic = axes_periodic(bc)
    total = 0.0
    for ax, sz in enumerate(sizes):
        if periodic[ax]:
            total += 2 * sz
            continue
        if procs is None:
            raise ValueError("clamped exchange accounting needs the mesh "
                             "shape (procs=(px, py, pz))")
        p = procs[ax]
        if coords is None:
            total += sz * 2 * (p - 1) / p
        else:
            total += sz * ((coords[ax] > 0) + (coords[ax] < p - 1))
    return fields * total


def exchange_bytes_per_step(M: int, g: int, S: int = 1, itemsize: int = 4, *,
                            bc: BoundarySpec | MixedBoundary | str = PERIODIC,
                            procs: tuple[int, int, int] | None = None,
                            coords: tuple[int, int, int] | None = None,
                            fields: int = 1) -> float:
    """Modelled ICI bytes per *timestep*: one width-S·g exchange funds S
    (clamped/mixed keyword accounting as in exchange_items_per_exchange;
    ``fields`` is the multi-field ×C factor)."""
    items = exchange_items_per_exchange(M, g, S, bc=bc, procs=procs,
                                        coords=coords, fields=fields)
    return itemsize * items / S


def distributed_bytes_per_step(M: int, T: int, g: int, n_steps: int,
                               itemsize: int = 4, *, S: int = 1,
                               bc: BoundarySpec | MixedBoundary | str = PERIODIC,
                               procs: tuple[int, int, int] | None = None,
                               coords: tuple[int, int, int] | None = None,
                               fields: int = 1) -> float:
    """Total modelled data movement per timestep of one mesh shard:
    HBM (fused resident model) + ICI (deep-exchange model) — the
    single-accounting number behind the distributed benchmark rows and
    DistributedPipeline.plan(), with both terms carrying the multi-field
    ×C ``fields`` factor. The HBM term is boundary-independent; the ICI
    term shrinks on clamped meshes (edge shards skip faces)."""
    return (resident_bytes_per_step(M, T, g, n_steps, itemsize, S=S,
                                    fields=fields)
            + exchange_bytes_per_step(M, g, S, itemsize, bc=bc, procs=procs,
                                      coords=coords, fields=fields))


# ---------------------------------------------------------------------------
# Communication-avoiding distributed pipeline (DESIGN.md §7)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DistributedPipeline:
    """K-step distributed stencil over a mesh of resident block stores.

    The communication-avoiding composition of the PR-1/PR-2 machinery
    with the halo exchange: every shard keeps its local state as the
    curve-ordered ``(nb, T, T, T)`` block store — stacked
    ``(C, nb, T, T, T)`` for a multi-field rule (DESIGN.md §9) — for the
    whole K-step loop (one permutation gather in, one out — never per
    step), packs *deep* width-S·g faces of every channel straight from
    that store via the precomputed index lists, and advances S whole
    timesteps per exchange through the fused kernel path
    (halo.shard_substeps). Bit-identical (f32) to S sequential
    :func:`repro.stencil.halo.make_distributed_step` steps.

    mesh:  3D device mesh over STENCIL_AXES (domain.make_stencil_mesh)
    spec:  element ordering of the public sharded state (shard_state)
    M:     local shard edge (power of 2); T: block edge (T | M, S·g | T)
    g:     stencil radius; S: substeps per exchange; rule: rules.py key
           (its ``channels`` selects the C of the store and state layout)
    bc:    boundary contract (core.boundary): "periodic" (torus wrap,
           default) | "dirichlet" | "neumann0" | a per-axis
           ``MixedBoundary``. Clamped runs open the exchange rings on
           their clamped axes (mesh-edge shards move no bytes across
           domain faces; their shell blocks carry boundary values
           instead) and refresh ghost layers per substep — S-deep rounds
           stay bit-identical (f32) to S sequential clamped steps
           (DESIGN.md §8).
    """
    mesh: jax.sharding.Mesh = field(compare=False)
    spec: OrderingSpec = field(default=None)  # type: ignore[assignment]
    M: int = 16
    T: int = 8
    g: int = 1
    S: int = 1
    rule: str = "gol"
    use_kernel: bool = False
    interpret: bool = True
    bc: BoundarySpec | MixedBoundary = PERIODIC

    def __post_init__(self):
        object.__setattr__(self, "bc", as_boundary(self.bc))
        assert self.spec is not None, "DistributedPipeline needs an OrderingSpec"
        assert self.M % self.T == 0, (self.M, self.T)
        if not self._valid_S(self.S):
            raise ValueError(
                f"distributed temporal blocking needs 1 <= S*g <= T and "
                f"S*g | T, got T={self.T}, g={self.g}, S={self.S}")

    _valid_S = ResidentPipeline._valid_S

    @property
    def kind(self) -> str:
        return stencil_block_kind(self.spec)

    @property
    def channels(self) -> int:
        return get_rule(self.rule).channels

    @property
    def procs(self) -> tuple[int, int, int]:
        return tuple(self.mesh.shape[a] for a in STENCIL_AXES)

    @property
    def global_shape(self) -> tuple[int, int, int]:
        """Per-axis global extents: the mesh may be non-cubic (4×2×1 …,
        DESIGN.md §10) as long as every *local* shard is a cubic
        power-of-2 block."""
        px, py, pz = self.procs
        return (px * self.M, py * self.M, pz * self.M)

    @property
    def global_M(self) -> int:
        px, py, pz = self.procs
        assert px == py == pz, self.procs
        return px * self.M

    # -- autotuner ---------------------------------------------------------
    @classmethod
    def plan(cls, mesh, spec: OrderingSpec, M: int, g: int = 1,
             rule: str = "gol", n_steps: int = 10, *,
             bc: BoundarySpec | MixedBoundary | str = PERIODIC,
             vmem_limit: int = VMEM_BUDGET_BYTES, max_S: int = 8,
             use_kernel: bool = False, interpret: bool = True,
             itemsize: int = 4) -> "DistributedPipeline":
        """Pick (T, S) minimising modelled HBM **plus ICI** bytes/step.

        Same enumeration as ResidentPipeline.plan, but the cost now
        carries the exchange term: S trades window inflation against
        both HBM amortisation and exchange frequency (the corner terms
        of a deep exchange grow with S·g), so the optimum can shift
        versus the single-device plan. Both terms carry the rule's ×C
        channel factor. Clamped ``bc`` shrinks the exchange term to the
        mesh-wide mean surface (edge shards skip faces on open rings),
        computed for this mesh's shape; a mixed contract shrinks only
        its clamped axes.
        """
        procs = tuple(mesh.shape[a] for a in STENCIL_AXES)
        C = get_rule(rule).channels
        T, S = _plan_search(
            M, g, max_S, vmem_limit, itemsize,
            lambda T, S: distributed_bytes_per_step(M, T, g, n_steps,
                                                    itemsize, S=S, bc=bc,
                                                    procs=procs, fields=C),
            fields=C)
        return cls(mesh=mesh, spec=spec, M=M, T=T, g=g, S=S, rule=rule,
                   bc=bc, use_kernel=use_kernel, interpret=interpret)

    # -- the K-step runner -------------------------------------------------
    def run_fn(self, n_steps: int):
        """jit'd (px,py,pz,[C,]M³) -> same: ceil(K/S) exchange+compute
        rounds.

        A K % S remainder runs as one shallower round when S·g-divisibility
        allows, else step by step — mirroring ResidentPipeline.run_fn.
        """
        full, rem = divmod(n_steps, self.S)
        if rem and not self._valid_S(rem):
            tail_rounds, tail_S = rem, 1
        else:
            tail_rounds, tail_S = (1, rem) if rem else (0, 0)
        C = self.channels
        pspec = _state_pspec(C)
        spec, kind, M, T = self.spec, self.kind, self.M, self.T
        nt = M // T
        round_kw = dict(kind=kind, M=M, g=self.g, rule=self.rule, bc=self.bc,
                        use_kernel=self.use_kernel, interpret=self.interpret)

        def local_run(state_path):  # (1,1,1,[C,]M³) per device
            perm = _store_perm_device(spec, kind, T, M, False)
            if C == 1:
                store = state_path.reshape(-1)[perm].reshape(nt ** 3, T, T, T)
            else:
                store = jnp.take(state_path.reshape(C, -1), perm, axis=-1)
                store = store.reshape(C, nt ** 3, T, T, T)
            if full:
                store = jax.lax.fori_loop(
                    0, full,
                    lambda _, st: shard_substeps(st, S=self.S, **round_kw),
                    store)
            if tail_rounds:
                store = jax.lax.fori_loop(
                    0, tail_rounds,
                    lambda _, st: shard_substeps(st, S=tail_S, **round_kw),
                    store)
            iperm = _store_perm_device(spec, kind, T, M, True)
            if C == 1:
                return store.reshape(-1)[iperm].reshape(1, 1, 1, -1)
            out = jnp.take(store.reshape(C, -1), iperm, axis=-1)
            return out.reshape(1, 1, 1, C, -1)

        # check_rep=False: pallas_call has no shard_map replication rule yet
        return jax.jit(shard_map(local_run, mesh=self.mesh, in_specs=pspec,
                                 out_specs=pspec, check_rep=False))

    def run(self, state: jnp.ndarray, n_steps: int) -> jnp.ndarray:
        """Advance a (px,py,pz,[C,]M³) sharded path-ordered state K steps."""
        return self.run_fn(n_steps)(state)

    def run_cube(self, cube: jnp.ndarray, n_steps: int) -> jnp.ndarray:
        """Convenience: shard a canonical global state — (Gk,Gi,Gj), or
        stacked (C,Gk,Gi,Gj) fields for a multi-field rule — run, gather
        back. Non-cubic meshes decompose a non-cubic global box into
        cubic M³ shards (DESIGN.md §10)."""
        st = shard_state(cube, self.spec, self.procs)
        st = self.run(st, n_steps)
        return unshard_state(st, self.spec, self.global_shape)

    # -- modelled traffic --------------------------------------------------
    def bytes_per_step(self, n_steps: int, itemsize: int = 4,
                       coords: tuple[int, int, int] | None = None) -> float:
        """HBM + ICI bytes per timestep: the mesh-wide mean shard by
        default, or the shard at mesh ``coords`` (clamped runs only
        differ per shard — edge shards skip faces)."""
        return distributed_bytes_per_step(self.M, self.T, self.g, n_steps,
                                          itemsize, S=self.S, bc=self.bc,
                                          procs=self.procs, coords=coords,
                                          fields=self.channels)

    def exchange_bytes_per_step(self, itemsize: int = 4,
                                coords: tuple[int, int, int] | None = None
                                ) -> float:
        return exchange_bytes_per_step(self.M, self.g, self.S, itemsize,
                                       bc=self.bc, procs=self.procs,
                                       coords=coords, fields=self.channels)

    def vmem_bytes(self, itemsize: int = 4) -> int:
        return fused_vmem_bytes(self.T, self.g, self.S, itemsize,
                                fields=self.channels)
