"""Fused resident-block-store stencil driver (DESIGN.md §3).

The paper's central claim is that SFC orderings pay off only when the
curve order *is* the storage order — reorder once, iterate many times
(§2, §4). This driver enforces that discipline for the gol3d workload:

    blockize once  →  K timesteps entirely in curve-ordered block form
                      (halo assembled in-kernel from the neighbour
                      tables, never materialised in HBM)
                   →  unblockize once.

The per-step state is exactly one ``(nb, T, T, T)`` block store — M³
elements, no ``((T+2g)/T)³`` halo duplication — and consecutive steps
ping-pong between two such stores: the K-step runner is jit'd with the
input store donated, so XLA aliases the output of step k as the input
of step k+1 (classic double buffering) instead of allocating per step.

``bytes_per_step`` quantifies the win over the repack pipeline
(kernels/ops.gol3d_step) for the benchmark trajectory.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.layout import blockize, unblockize
from repro.core.neighbors import neighbor_table_device
from repro.kernels import ref as kref
from repro.kernels.ops import uniform_weights
from repro.kernels.stencil3d import stencil_sum_resident

__all__ = ["ResidentPipeline", "repack_bytes_per_step", "resident_bytes_per_step"]


@dataclass(frozen=True)
class ResidentPipeline:
    """gol3d over a persistent curve-ordered block store.

    M:          cube edge (power of 2)
    T:          block edge (T | M; g | T for the kernel path)
    g:          stencil radius (periodic boundaries)
    kind:       block-grid curve — "morton" | "hilbert" | "row_major" |
                "column_major" (core.neighbors.block_kind_of maps an
                OrderingSpec here)
    use_kernel: Pallas resident kernel (interpret on CPU) vs jnp oracle
    """
    M: int
    T: int = 8
    g: int = 1
    kind: str = "morton"
    use_kernel: bool = False
    interpret: bool = True

    def __post_init__(self):
        assert self.M % self.T == 0, (self.M, self.T)

    @property
    def nt(self) -> int:
        return self.M // self.T

    @property
    def nb(self) -> int:
        return self.nt ** 3

    # -- layout boundary (paid once per K-step run, not per step) ---------
    def to_blocks(self, cube: jnp.ndarray) -> jnp.ndarray:
        return blockize(cube, self.T, kind=self.kind)

    def to_cube(self, store: jnp.ndarray) -> jnp.ndarray:
        return unblockize(store, self.M, kind=self.kind)

    # -- the resident step -------------------------------------------------
    def step_fn(self):
        """(store -> store) single gol3d update, all in block order."""
        g, w = self.g, uniform_weights(self.g)
        nbr = neighbor_table_device(self.kind, self.nt)
        use_kernel, interpret = self.use_kernel, self.interpret

        def step(store):
            if use_kernel:
                neigh = stencil_sum_resident(store, w, nbr, g=g,
                                             interpret=interpret)
            else:
                neigh = kref.stencil_sum_resident_ref(store, w, nbr)
            return kref.gol_rule_ref(store, neigh, g).astype(store.dtype)

        return step

    def run_fn(self, n_steps: int):
        """jit'd fused K-step runner over the donated (double-buffered) store."""
        step = self.step_fn()
        donate = (0,) if jax.default_backend() != "cpu" else ()

        @functools.partial(jax.jit, donate_argnums=donate)
        def run(store):
            return jax.lax.fori_loop(0, n_steps, lambda _, s: step(s), store)

        return run

    def run(self, cube: jnp.ndarray, n_steps: int) -> jnp.ndarray:
        """blockize once → n_steps fused curve-ordered updates → unblockize."""
        store = self.to_blocks(cube)
        store = self.run_fn(n_steps)(store)
        return self.to_cube(store)

    # -- modelled HBM traffic (benchmarks/stencil_update.py) ---------------
    def bytes_per_step(self, n_steps: int, itemsize: int = 4) -> float:
        return resident_bytes_per_step(self.M, self.T, self.g, n_steps,
                                       itemsize)


def repack_bytes_per_step(M: int, T: int, g: int, itemsize: int = 4) -> float:
    """Modelled HBM bytes per step of the repack pipeline (ops.gol3d_step).

    Every step: read the M³ cube, write the halo-duplicated (nb·(T+2g)³)
    store, stream it back through the kernel, write nb·T³ partial sums,
    then read them again to rebuild the canonical cube. The
    ((T+2g)/T)³ inflation and the O(M³) repack recur each step.
    """
    nb = (M // T) ** 3
    W3 = (T + 2 * g) ** 3
    cube, halo, out = M ** 3, nb * W3, nb * T ** 3
    #      repack read + halo write + kernel read + kernel write
    #      + rule read/write + unblockize read + cube write
    return itemsize * float(cube + halo + halo + out + 2 * out + out + cube)


def resident_bytes_per_step(M: int, T: int, g: int, n_steps: int,
                            itemsize: int = 4) -> float:
    """Modelled HBM bytes per step of the resident pipeline, amortised.

    Per step the kernel reads exactly (T+2g)³ per block (centre + halo
    slices gathered from neighbour blocks — no duplicated halo store)
    and writes T³; the rule pass reads/writes the T³ store. The one-off
    blockize/unblockize (read M³ + write M³ each) amortises over K.
    """
    nb = (M // T) ** 3
    W3 = (T + 2 * g) ** 3
    cube, out = M ** 3, nb * T ** 3
    per_step = nb * W3 + out + 2 * out
    boundary = 2 * (2 * cube)  # blockize + unblockize, once per run
    return itemsize * float(per_step + boundary / max(n_steps, 1))
