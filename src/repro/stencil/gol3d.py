"""gol3d — the paper's stencil application (§4), in JAX.

Extends Game of Life to 3D with a runtime-selectable stencil radius g
(the paper's cube of size 2g+1). State is stored under a selectable
ordering; the update walks the cube along the ordering's path, realised
on TPU as the SFC-blocked kernel pipeline (kernels/stencil3d.py) whose
grid order follows the curve because the blocks are laid out along it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OrderingSpec, ROW_MAJOR, apply_ordering, undo_ordering
from repro.kernels import ops
from repro.kernels import ref as kref

__all__ = ["Gol3dConfig", "Gol3d"]


@dataclass(frozen=True)
class Gol3dConfig:
    M: int = 64                      # cube edge (power of 2)
    g: int = 1                       # stencil radius
    ordering: OrderingSpec = ROW_MAJOR
    block_T: int = 8                 # SFC block edge for the kernel pipeline
    use_kernel: bool = False         # Pallas kernel (interpret on CPU) vs jnp
    density: float = 0.3             # initial live fraction
    seed: int = 0


@dataclass
class Gol3d:
    cfg: Gol3dConfig
    state_path: jnp.ndarray = field(init=False)  # (M³,) in ordering order

    def __post_init__(self):
        rng = np.random.default_rng(self.cfg.seed)
        cube = (rng.random((self.cfg.M,) * 3) < self.cfg.density).astype(np.float32)
        self.state_path = apply_ordering(jnp.asarray(cube), self.cfg.ordering)

    @property
    def cube(self) -> jnp.ndarray:
        return undo_ordering(self.state_path, self.cfg.ordering, self.cfg.M)

    def step_fn(self):
        """jit-able (state_path -> state_path) single update."""
        cfg = self.cfg
        kind = ("morton" if cfg.ordering.kind not in ("morton", "hilbert")
                else cfg.ordering.kind)

        @jax.jit
        def step(state_path):
            cube = undo_ordering(state_path, cfg.ordering, cfg.M)
            nxt = ops.gol3d_step(cube, g=cfg.g, T=cfg.block_T, block_kind=kind,
                                 use_kernel=cfg.use_kernel)
            return apply_ordering(nxt, cfg.ordering)

        return step

    def run(self, n_steps: int) -> jnp.ndarray:
        step = self.step_fn()
        s = self.state_path
        for _ in range(n_steps):
            s = step(s)
        self.state_path = jax.block_until_ready(s)
        return self.state_path

    def reference_run(self, n_steps: int) -> jnp.ndarray:
        """Ordering-independent oracle on the canonical cube."""
        cube = self.cube
        for _ in range(n_steps):
            cube = kref.gol3d_step_ref(cube, self.cfg.g)
        return cube
