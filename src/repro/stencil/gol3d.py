"""gol3d — the paper's stencil application (§4), in JAX.

Extends Game of Life to 3D with a runtime-selectable stencil radius g
(the paper's cube of size 2g+1). State is stored under a selectable
ordering; the update walks the cube along the ordering's path, realised
on TPU as the SFC-blocked kernel pipeline (kernels/stencil3d.py) whose
grid order follows the curve because the blocks are laid out along it.

Two execution modes (DESIGN.md §3):

- per-step *repack* (``step_fn``/``run``): each step rebuilds the
  halo-extended block store from the canonical cube — the seed pipeline,
  kept as the equivalence baseline;
- fused *resident* (``run_resident``): blockize once, run K steps on the
  persistent curve-ordered store with in-kernel halo streaming
  (stencil/pipeline.py), unblockize once. ``substeps`` (S) additionally
  temporal-blocks the resident form — S whole updates per HBM
  round-trip (DESIGN.md §4); ``substeps=0`` lets the pipeline's
  ``plan()`` autotuner pick (T, S) under the VMEM budget.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (OrderingSpec, PERIODIC, ROW_MAJOR, BoundarySpec,
                        apply_ordering, as_boundary, undo_ordering)
from repro.kernels import ops
from repro.kernels import ref as kref

from .domain import Decomposition3D, STENCIL_AXES
from .halo import stencil_block_kind
from .pipeline import DistributedPipeline, ResidentPipeline

__all__ = ["Gol3dConfig", "Gol3d"]


@dataclass(frozen=True)
class Gol3dConfig:
    """Static configuration of one gol3d run (hashable: rides jit keys).

    M:          cube edge (power of 2)
    g:          stencil radius — the update reads a (2g+1)³ tap cube
    ordering:   storage ordering of the public path state (core.orderings)
    block_T:    SFC block edge of the kernel pipelines (T | M)
    substeps:   S fused timesteps per HBM round-trip (temporal blocking,
                DESIGN.md §4); 0 delegates (T, S) to the plan() autotuners
    use_kernel: Pallas kernels (interpret mode off-TPU) vs jnp oracles
    bc:         boundary contract (core.boundary.BoundarySpec or kind
                string): "periodic" wraps like a torus; "dirichlet" /
                "neumann0" clamp the domain edges physically
                (DESIGN.md §8) — every execution mode (repack, resident,
                distributed) honours the same contract
    density:    initial live fraction of the random seed state
    seed:       RNG seed of the initial state
    """
    M: int = 64                      # cube edge (power of 2)
    g: int = 1                       # stencil radius
    ordering: OrderingSpec = ROW_MAJOR
    block_T: int = 8                 # SFC block edge for the kernel pipeline
    substeps: int = 1                # S per fused launch; 0 = autotune (T, S)
    use_kernel: bool = False         # Pallas kernel (interpret on CPU) vs jnp
    density: float = 0.3             # initial live fraction
    seed: int = 0
    bc: BoundarySpec = PERIODIC      # boundary contract (core.boundary)

    def __post_init__(self):
        object.__setattr__(self, "bc", as_boundary(self.bc))


@dataclass
class Gol3d:
    cfg: Gol3dConfig
    state_path: jnp.ndarray = field(init=False)  # (M³,) in ordering order

    def __post_init__(self):
        rng = np.random.default_rng(self.cfg.seed)
        cube = (rng.random((self.cfg.M,) * 3) < self.cfg.density).astype(np.float32)
        self.state_path = apply_ordering(jnp.asarray(cube), self.cfg.ordering)

    @property
    def cube(self) -> jnp.ndarray:
        return undo_ordering(self.state_path, self.cfg.ordering, self.cfg.M)

    @property
    def block_kind(self) -> str:
        """Block-grid curve for the kernel pipelines: the ordering's own
        curve when it has one, else Morton (the pipeline is SFC-blocked
        even when the logical state ordering is row/column-major)."""
        return stencil_block_kind(self.cfg.ordering)

    def step_fn(self):
        """jit-able (state_path -> state_path) single update (repack mode)."""
        cfg = self.cfg
        kind = self.block_kind

        @jax.jit
        def step(state_path):
            cube = undo_ordering(state_path, cfg.ordering, cfg.M)
            nxt = ops.gol3d_step(cube, g=cfg.g, T=cfg.block_T, block_kind=kind,
                                 use_kernel=cfg.use_kernel, bc=cfg.bc)
            return apply_ordering(nxt, cfg.ordering)

        return step

    def run(self, n_steps: int) -> jnp.ndarray:
        step = self.step_fn()
        s = self.state_path
        for _ in range(n_steps):
            s = step(s)
        self.state_path = jax.block_until_ready(s)
        return self.state_path

    def resident_pipeline(self) -> ResidentPipeline:
        """The fused driver over this app's block layout (DESIGN.md §3–§4).

        ``cfg.substeps`` threads straight through as the pipeline's S;
        ``substeps=0`` delegates (T, S) to the ``plan()`` autotuner.
        """
        cfg = self.cfg
        if cfg.substeps == 0:
            return ResidentPipeline.plan(cfg.M, g=cfg.g, kind=self.block_kind,
                                         bc=cfg.bc, use_kernel=cfg.use_kernel)
        return ResidentPipeline(M=cfg.M, T=cfg.block_T, g=cfg.g,
                                kind=self.block_kind, S=cfg.substeps,
                                bc=cfg.bc, use_kernel=cfg.use_kernel)

    def run_resident(self, n_steps: int) -> jnp.ndarray:
        """Fused multi-step run: the curve-ordered block store is the
        resident state for all n_steps; layout conversions happen once at
        each end. Bit-identical to ``run`` (same block kind, same rule)."""
        pipe = self.resident_pipeline()
        cube = pipe.run(self.cube, n_steps)
        self.state_path = jax.block_until_ready(apply_ordering(cube, self.cfg.ordering))
        return self.state_path

    def distributed_pipeline(self, mesh: jax.sharding.Mesh) -> DistributedPipeline:
        """Communication-avoiding mesh pipeline over this app's layout.

        Decomposes the cfg.M cube onto ``mesh`` (cubic power-of-2 local
        blocks, Decomposition3D), threads ``cfg.substeps`` through as the
        exchange depth S (``substeps=0`` delegates (T, S) to the
        exchange-aware ``DistributedPipeline.plan``).
        """
        cfg = self.cfg
        procs = tuple(mesh.shape[a] for a in STENCIL_AXES)
        local = Decomposition3D(cfg.M, procs).check_local_pow2_cube()
        if cfg.substeps == 0:
            return DistributedPipeline.plan(mesh, cfg.ordering, local,
                                            g=cfg.g, bc=cfg.bc,
                                            use_kernel=cfg.use_kernel)
        T = min(cfg.block_T, local)
        return DistributedPipeline(mesh=mesh, spec=cfg.ordering, M=local,
                                   T=T, g=cfg.g, S=cfg.substeps, bc=cfg.bc,
                                   use_kernel=cfg.use_kernel)

    def run_distributed(self, mesh: jax.sharding.Mesh, n_steps: int) -> jnp.ndarray:
        """Shard the cube over the mesh, run K deep-exchange rounds, and
        gather back into this app's path-ordered state. Bit-identical to
        ``run``/``run_resident`` on one device (same rule, f32 state)."""
        pipe = self.distributed_pipeline(mesh)
        cube = pipe.run_cube(self.cube, n_steps)
        self.state_path = jax.block_until_ready(
            apply_ordering(cube, self.cfg.ordering))
        return self.state_path

    def reference_run(self, n_steps: int) -> jnp.ndarray:
        """Ordering-independent oracle on the canonical cube (same bc)."""
        cube = self.cube
        for _ in range(n_steps):
            cube = kref.gol3d_step_ref(cube, self.cfg.g, bc=self.cfg.bc)
        return cube
