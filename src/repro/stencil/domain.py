"""3D domain decomposition over a device mesh."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["Decomposition3D", "make_stencil_mesh"]

STENCIL_AXES = ("dx", "dy", "dz")


def make_stencil_mesh(shape: tuple[int, int, int]) -> jax.sharding.Mesh:
    """Mesh for the stencil app. Axis order (dx,dy,dz) = (slab,row,col)."""
    return jax.make_mesh(shape, STENCIL_AXES)


@dataclass(frozen=True)
class Decomposition3D:
    """Global (Mg)³ cube split into P = px·py·pz local (Mg/p)³ blocks."""
    global_M: int
    procs: tuple[int, int, int]

    @property
    def local_shape(self) -> tuple[int, int, int]:
        px, py, pz = self.procs
        assert self.global_M % px == 0 and self.global_M % py == 0 \
            and self.global_M % pz == 0, (self.global_M, self.procs)
        return (self.global_M // px, self.global_M // py, self.global_M // pz)

    def check_local_pow2_cube(self) -> int:
        """SFC orderings need the local block to be a 2^m cube."""
        lx, ly, lz = self.local_shape
        if not (lx == ly == lz):
            raise ValueError(f"local block must be cubic, got {self.local_shape}")
        m = int(lx).bit_length() - 1
        if (1 << m) != lx:
            raise ValueError(f"local edge must be a power of 2, got {lx}")
        return lx
