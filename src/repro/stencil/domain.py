"""3D domain decomposition over a device mesh."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["Decomposition3D", "make_stencil_mesh"]

STENCIL_AXES = ("dx", "dy", "dz")


def make_stencil_mesh(shape: tuple[int, int, int]) -> jax.sharding.Mesh:
    """Mesh for the stencil app. Axis order (dx,dy,dz) = (slab,row,col).

    Elasticity (DESIGN.md §10): ``shape`` may cover *fewer* devices than
    the process has — a resumed run that lost part of its machine builds
    its smaller mesh from a prefix of ``jax.devices()`` — so a 2×2×1
    mesh is valid on an 8-device host. When the shape covers the whole
    machine this defers to ``jax.make_mesh`` (which picks an
    ICI-friendly device order on real hardware).
    """
    n = int(np.prod(shape))
    devices = jax.devices()
    if n == len(devices):
        return jax.make_mesh(shape, STENCIL_AXES)
    if n > len(devices):
        raise ValueError(f"mesh shape {shape} needs {n} devices, "
                         f"have {len(devices)}")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), STENCIL_AXES)


def _as_shape3(global_shape) -> tuple[int, int, int]:
    """Coerce a cube edge or per-axis extent triple to a 3-tuple."""
    if isinstance(global_shape, (int, np.integer)):
        return (int(global_shape),) * 3
    gk, gi, gj = (int(x) for x in global_shape)
    return (gk, gi, gj)


@dataclass(frozen=True)
class Decomposition3D:
    """Global domain split into P = px·py·pz local blocks.

    ``global_M`` is a cube edge (the paper's M³ domain) or a per-axis
    ``(Gk, Gi, Gj)`` extent triple — a non-cubic process grid such as
    4×2×1 decomposes a non-cubic global box into *cubic* local shards
    (the SFC machinery needs cubic power-of-2 local blocks; the global
    box may be any multiple of them, DESIGN.md §10).
    """
    global_M: "int | tuple[int, int, int]"
    procs: tuple[int, int, int]

    @property
    def global_shape(self) -> tuple[int, int, int]:
        return _as_shape3(self.global_M)

    @property
    def local_shape(self) -> tuple[int, int, int]:
        gk, gi, gj = self.global_shape
        px, py, pz = self.procs
        assert gk % px == 0 and gi % py == 0 and gj % pz == 0, \
            (self.global_shape, self.procs)
        return (gk // px, gi // py, gj // pz)

    def check_local_pow2_cube(self) -> int:
        """SFC orderings need the local block to be a 2^m cube."""
        lx, ly, lz = self.local_shape
        if not (lx == ly == lz):
            raise ValueError(f"local block must be cubic, got {self.local_shape}")
        m = int(lx).bit_length() - 1
        if (1 << m) != lx:
            raise ValueError(f"local edge must be a power of 2, got {lx}")
        return lx
